"""The 10 assigned architectures, exact configs from public literature.

Each entry: full ModelConfig + a reduced same-family smoke config (run on
CPU in tests) + the shape cells it participates in.
"""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.model import ModelConfig

_STD_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
_LONG_SHAPES = _STD_SHAPES + ("long_500k",)


ARCHS: dict[str, ArchConfig] = {}


def _reg(arch: ArchConfig) -> ArchConfig:
    ARCHS[arch.name] = arch
    return arch


# -- whisper-base [audio] enc-dec, conv frontend stubbed ----------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="whisper-base",
            family="encdec",
            n_layers=6,
            enc_layers=6,
            d_model=512,
            n_heads=8,
            n_kv_heads=8,
            d_ff=2048,
            vocab=51865,
            mlp_kind="gelu",
        ),
        smoke=ModelConfig(
            name="whisper-smoke", family="encdec", n_layers=2, enc_layers=2,
            d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
            mlp_kind="gelu", loss_chunk=16, attn_block=16,
        ),
        source="arXiv:2212.04356",
    )
)

# -- llava-next-mistral-7b [vlm]: mistral backbone + anyres patch stub --------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="llava-next-mistral-7b",
            family="dense",
            n_layers=32,
            d_model=4096,
            n_heads=32,
            n_kv_heads=8,
            d_ff=14336,
            vocab=32000,
            frontend="vision_stub",
            frontend_tokens=2880,   # anyres: base 576 + 4 tiles x 576
            loss_chunk=64,          # must divide the 1216 text positions
        ),
        smoke=ModelConfig(
            name="llava-smoke", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
            frontend="vision_stub", frontend_tokens=16, loss_chunk=16,
            attn_block=16,
        ),
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )
)

# -- zamba2-2.7b [hybrid]: mamba2 backbone + shared attention block -----------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="zamba2-2.7b",
            family="hybrid",
            n_layers=54,
            d_model=2560,
            n_heads=32,
            n_kv_heads=32,
            d_ff=10240,
            vocab=32000,
            ssm_state=64,
            ssm_expansion=2,
            ssm_groups=1,
            shared_attn_every=6,
        ),
        smoke=ModelConfig(
            name="zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
            n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, ssm_state=16,
            ssm_expansion=2, ssm_groups=1, shared_attn_every=2,
            ssm_chunk=16, loss_chunk=16, attn_block=16,
        ),
        shapes=_LONG_SHAPES,
        skip_notes=(),
        source="arXiv:2411.15242",
    )
)

# -- yi-9b [dense] -------------------------------------------------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="yi-9b", family="dense", n_layers=48, d_model=4096,
            n_heads=32, n_kv_heads=4, d_ff=11008, vocab=64000,
        ),
        smoke=ModelConfig(
            name="yi-smoke", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, loss_chunk=16,
            attn_block=16,
        ),
        source="arXiv:2403.04652",
    )
)

# -- minitron-8b [dense]: pruned nemotron, 256 K vocab -------------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="minitron-8b", family="dense", n_layers=32, d_model=4096,
            n_heads=32, n_kv_heads=8, d_ff=16384, vocab=256000,
            loss_chunk=64,          # 256 K vocab: smaller CE tiles
        ),
        smoke=ModelConfig(
            name="minitron-smoke", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, loss_chunk=16,
            attn_block=16,
        ),
        source="arXiv:2407.14679",
    )
)

# -- qwen1.5-4b [dense]: QKV bias ----------------------------------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
            n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151936,
            qkv_bias=True, loss_chunk=64,
        ),
        smoke=ModelConfig(
            name="qwen-smoke", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, qkv_bias=True,
            loss_chunk=16, attn_block=16,
        ),
        source="hf:Qwen/Qwen1.5-4B",
    )
)

# -- starcoder2-7b [dense]: GQA + RoPE, GELU MLP -------------------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
            n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152,
            mlp_kind="gelu", qkv_bias=True,
        ),
        smoke=ModelConfig(
            name="starcoder2-smoke", family="dense", n_layers=2, d_model=72,
            n_heads=4, n_kv_heads=2, d_ff=144, vocab=256, mlp_kind="gelu",
            qkv_bias=True, loss_chunk=16, attn_block=16,
        ),
        source="arXiv:2402.19173",
    )
)

# -- xlstm-125m [ssm]: sLSTM + mLSTM blocks ------------------------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="xlstm-125m", family="xlstm", n_layers=12, d_model=768,
            n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=8,
        ),
        smoke=ModelConfig(
            name="xlstm-smoke", family="xlstm", n_layers=4, d_model=64,
            n_heads=4, n_kv_heads=4, d_ff=0, vocab=256, slstm_every=4,
            ssm_chunk=16, loss_chunk=16,
        ),
        shapes=_LONG_SHAPES,
        skip_notes=(),
        source="arXiv:2405.04517",
    )
)

# -- deepseek-v2-lite-16b [moe]: MLA + 2 shared + 64 routed top-6 --------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="deepseek-v2-lite-16b", family="moe", n_layers=27,
            d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
            vocab=102400, moe_experts=64, moe_top_k=6, moe_shared=2,
            moe_d_ff=1408, moe_dense_first_n=1, mla_kv_lora=512,
            mla_qk_nope=128, mla_qk_rope=64, mla_v_head=128, loss_chunk=64,
        ),
        smoke=ModelConfig(
            name="dsv2-smoke", family="moe", n_layers=3, d_model=64,
            n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, moe_experts=4,
            moe_top_k=2, moe_shared=1, moe_d_ff=64, moe_dense_first_n=1,
            mla_kv_lora=32, mla_qk_nope=16, mla_qk_rope=8, mla_v_head=16,
            loss_chunk=16, attn_block=16,
        ),
        source="arXiv:2405.04434",
    )
)

# -- dbrx-132b [moe]: 16 experts top-4 ------------------------------------------
_reg(
    ArchConfig(
        model=ModelConfig(
            name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
            n_heads=48, n_kv_heads=8, d_ff=0, vocab=100352, moe_experts=16,
            moe_top_k=4, moe_d_ff=10752, loss_chunk=64,
        ),
        smoke=ModelConfig(
            name="dbrx-smoke", family="moe", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=0, vocab=256, moe_experts=4,
            moe_top_k=2, moe_d_ff=64, loss_chunk=16, attn_block=16,
        ),
        source="hf:databricks/dbrx-base",
    )
)


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def arch_names() -> list[str]:
    return list(ARCHS)


def cells() -> list[tuple[str, str]]:
    """All runnable (arch, shape) cells."""
    out = []
    for name, arch in ARCHS.items():
        for shape in arch.shapes:
            out.append((name, shape))
    return out
