"""Generate EXPERIMENTS.md tables from the dry-run sweep JSONs.

Reads experiments/dryrun_baseline_v2 (paper-faithful substrate, perf
optimizations disabled) and experiments/dryrun_opt (optimized), and the
benchmark CSV, and prints the §Dry-run/§Roofline/§Perf markdown tables.
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def load(dirname: str) -> dict:
    out = {}
    for f in glob.glob(os.path.join(HERE, dirname, "*.json")):
        d = json.load(open(f))
        if "roofline" in d:
            out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def gib(x) -> str:
    return f"{(x or 0) / 2**30:.1f}"


def roofline_table(cells: dict, mesh: str) -> str:
    rows = [
        "| arch | shape | temp GiB/chip | t_comp | t_mem | t_coll | "
        "bottleneck | useful | roofline% |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        r = d["roofline"]
        rows.append(
            f"| {a} | {s} | {gib(d['memory_analysis']['bytes_per_device'])} | "
            f"{fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} | "
            f"{fmt_s(r['t_collective_s'])} | {r['bottleneck']} | "
            f"{r['useful_flop_ratio']:.2f} | "
            f"{100 * r['roofline_fraction']:.2f} |"
        )
    return "\n".join(rows)


def before_after(base: dict, opt: dict, mesh: str = "pod16x16") -> str:
    rows = [
        "| arch | shape | t_mem before→after | t_coll before→after | "
        "roofline% before→after |",
        "|---|---|---|---|---|",
    ]
    for key in sorted(opt):
        a, s, m = key
        if m != mesh or (a, s, m) not in base:
            continue
        rb, ro = base[key]["roofline"], opt[key]["roofline"]
        rows.append(
            f"| {a} | {s} | {fmt_s(rb['t_memory_s'])} → "
            f"{fmt_s(ro['t_memory_s'])} | {fmt_s(rb['t_collective_s'])} → "
            f"{fmt_s(ro['t_collective_s'])} | "
            f"{100 * rb['roofline_fraction']:.2f} → "
            f"{100 * ro['roofline_fraction']:.2f} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    base = load("dryrun_baseline_v2")
    opt = load("dryrun_opt")
    print("## Optimized roofline (single pod, 16x16)\n")
    print(roofline_table(opt, "pod16x16"))
    print("\n## Optimized roofline (multi-pod, 2x16x16)\n")
    print(roofline_table(opt, "pod2x16x16"))
    print("\n## Before/after (baseline vs optimized, single pod)\n")
    print(before_after(base, opt))
