import functools
import os
import subprocess
import sys

import pytest

TESTS_DIR = os.path.dirname(__file__)
SRC_DIR = os.path.abspath(os.path.join(TESTS_DIR, "..", "src"))
sys.path.insert(0, SRC_DIR)

# Multi-device tests run in subprocesses with a forced 8-way host platform
# (the main test process keeps seeing 1 CPU device, per the dry-run
# isolation rule — see tests/test_multidevice.py).
MULTIDEVICE_XLA_FLAGS = "--xla_force_host_platform_device_count=8"


def multidevice_subprocess_env() -> dict:
    """Environment for a subprocess that needs 8 host devices + repro."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " " + MULTIDEVICE_XLA_FLAGS
    ).strip()
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    return env


@functools.lru_cache(maxsize=None)
def _forced_device_count() -> int:
    probe = "import jax; print(jax.device_count())"
    try:
        proc = subprocess.run(
            [sys.executable, "-c", probe],
            env=multidevice_subprocess_env(),
            capture_output=True,
            text=True,
            timeout=300,
        )
        return int(proc.stdout.strip().splitlines()[-1])
    except Exception:
        return 0


@pytest.fixture
def multidevice_env() -> dict:
    """Skips cleanly when 8 forced host devices can't be satisfied."""
    n = _forced_device_count()
    if n < 8:
        pytest.skip(
            f"{MULTIDEVICE_XLA_FLAGS} yields {n} devices (need 8) on this "
            "platform"
        )
    return multidevice_subprocess_env()
