"""GF(2^8) field properties (hypothesis) + bit-matrix/bit-plane identities."""

import numpy as np
import pytest
try:
    from hypothesis import given
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given
    from _hypothesis_shim import strategies as st

from repro.core import gf256 as g

bytes_st = st.integers(min_value=0, max_value=255)


@given(bytes_st, bytes_st, bytes_st)
def test_field_axioms(a, b, c):
    # commutativity, associativity, distributivity over XOR (field addition)
    assert g.gf_mul(a, b) == g.gf_mul(b, a)
    assert g.gf_mul(a, g.gf_mul(b, c)) == g.gf_mul(g.gf_mul(a, b), c)
    assert g.gf_mul(a, b ^ c) == g.gf_mul(a, b) ^ g.gf_mul(a, c)
    assert g.gf_mul(a, 1) == a
    assert g.gf_mul(a, 0) == 0


@given(st.integers(min_value=1, max_value=255))
def test_inverse(a):
    assert g.gf_mul(a, g.gf_inv(a)) == 1
    assert g.gf_div(a, a) == 1


@given(bytes_st, st.integers(min_value=0, max_value=20))
def test_pow(a, n):
    acc = 1
    for _ in range(n):
        acc = g.gf_mul(acc, a)
    assert g.gf_pow(a, n) == acc


@given(bytes_st, bytes_st)
def test_bitmatrix_multiply(coef, x):
    m = g.mul_bitmatrix(coef)
    bits = np.array([(x >> j) & 1 for j in range(8)], dtype=np.uint8)
    got = (m @ bits) % 2
    want = np.array([(g.gf_mul(coef, x) >> j) & 1 for j in range(8)])
    assert np.array_equal(got, want)


@given(st.binary(min_size=32, max_size=512).filter(lambda b: len(b) % 32 == 0))
def test_bitplane_roundtrip(data):
    arr = np.frombuffer(data, dtype=np.uint8)
    assert np.array_equal(g.bitplanes_to_bytes(g.bytes_to_bitplanes(arr)), arr)


def test_full_mul_table_matches_scalar():
    t = g.full_mul_table()
    rng = np.random.default_rng(0)
    for a, b in rng.integers(0, 256, (100, 2)):
        assert t[a, b] == g.gf_mul(int(a), int(b))


@pytest.mark.parametrize("kind", ["cauchy", "vandermonde"])
@pytest.mark.parametrize("k,m", [(2, 1), (3, 2), (4, 3), (6, 3)])
def test_generator_is_mds(kind, k, m):
    """Every k x k submatrix of [I; P] invertible => any m losses decode."""
    import itertools

    gm = g.generator_matrix(k, m, kind)
    for rows in itertools.combinations(range(k + m), k):
        g.gf_mat_inv(gm[list(rows)])  # raises LinAlgError if singular


def test_matmul_inverse_roundtrip():
    rng = np.random.default_rng(1)
    for n in (2, 4, 6):
        while True:
            a = rng.integers(0, 256, (n, n), dtype=np.uint8)
            try:
                inv = g.gf_mat_inv(a)
                break
            except np.linalg.LinAlgError:
                continue
        prod = g.gf_matmul(a, inv)
        assert np.array_equal(prod, np.eye(n, dtype=np.uint8))
