"""Linearizability: the checker itself, then the consistency protocols.

(a) checker self-tests — hand-written histories with known verdicts
    (linearizable and violating), pending-operation semantics, and
    counterexample quality;
(b) protocol proofs — the functional-plane chain (CRAQ) and ABD harness
    runs across a seeded crash x loss x straggler grid, every history
    checked; the full grid rides in the slow lane, a reduced grid in
    tier 1;
(c) mutation test — a chain whose tail skips the version bump
    (``tail_bump=False``) acks writes that never commit; the checker
    must flag the resulting stale reads.
"""

import random

import pytest

from repro.core.handlers import ReplicationHarness
from repro.policy import Chain, PolicySpec, Quorum, SpongeAuth
from repro.verify.linearize import (
    CheckResult,
    Operation,
    check_history,
    check_records,
    operations_from_records,
)

pytestmark = pytest.mark.linearize


def op(op_id, client, kind, key, value, invoke, response):
    return Operation(op_id, client, kind, key, value, invoke, response)


# -- (a) checker self-tests --------------------------------------------------


def test_empty_and_single_op_histories():
    assert check_history([]).ok
    assert check_history([op(1, 1, "write", 0, 7, 1, 2)]).ok
    # a read of the initial value is legal...
    assert check_history([op(1, 1, "read", 0, 0, 1, 2)]).ok
    # ...but a read of a never-written value is not
    assert not check_history([op(1, 1, "read", 0, 7, 1, 2)]).ok


def test_sequential_read_your_write():
    h = [op(1, 1, "write", 0, 7, 1, 2), op(2, 1, "read", 0, 7, 3, 4)]
    assert check_history(h).ok
    # stale read strictly after the write's response: violation
    h = [op(1, 1, "write", 0, 7, 1, 2), op(2, 1, "read", 0, 0, 3, 4)]
    assert not check_history(h).ok


def test_concurrent_write_read_both_outcomes_legal():
    # read overlaps the write: returning either the old or the new value
    # is linearizable (the point floats within the overlap)
    w = op(1, 1, "write", 0, 7, 1, 10)
    assert check_history([w, op(2, 2, "read", 0, 7, 2, 9)]).ok
    assert check_history([w, op(2, 2, "read", 0, 0, 2, 9)]).ok


def test_new_old_inversion_is_flagged():
    # classic non-linearizable pattern: two sequential reads observe the
    # new value then the old one
    h = [
        op(1, 1, "write", 0, 7, 1, 20),
        op(2, 2, "read", 0, 7, 2, 5),    # saw the write
        op(3, 2, "read", 0, 0, 6, 9),    # then un-saw it
    ]
    res = check_history(h)
    assert not res.ok
    assert res.key == 0


def test_keys_are_independent_registers():
    h = [
        op(1, 1, "write", 0, 7, 1, 2),
        op(2, 1, "write", 1, 9, 3, 4),
        op(3, 2, "read", 0, 7, 5, 6),
        op(4, 2, "read", 1, 9, 7, 8),
    ]
    assert check_history(h).ok
    # same interleaving, but the key-1 read observes key-0's value
    h[3] = op(4, 2, "read", 1, 7, 7, 8)
    res = check_history(h)
    assert not res.ok and res.key == 1


def test_pending_write_may_or_may_not_apply():
    # a crashed client's write never completed: a later read may see it
    # (it reached the replicas) or not (it was lost) — both linearizable
    w = op(1, 1, "write", 0, 7, 1, None)
    assert check_history([w, op(2, 2, "read", 0, 7, 5, 6)]).ok
    assert check_history([w, op(2, 2, "read", 0, 0, 5, 6)]).ok
    # but flickering between applied and not applied is a violation
    res = check_history([
        w,
        op(2, 2, "read", 0, 7, 5, 6),
        op(3, 2, "read", 0, 0, 7, 8),
    ])
    assert not res.ok


def test_pending_reads_are_dropped():
    h = [op(1, 1, "read", 0, None, 1, None)]
    res = check_history(h)
    assert res.ok and res.checked == 0


def test_counterexample_names_the_stuck_read():
    h = [
        op(1, 1, "write", 0, 7, 1, 2),
        op(2, 2, "read", 0, 0, 3, 4),
    ]
    res = check_history(h)
    assert not res.ok
    text = res.explain()
    assert "returned 0" in text and "holds 7" in text
    # the longest partial linearization got through the write
    assert res.partial == (1,)


def test_counterexample_printer_golden():
    # the full printed artifact, frozen: a pending write flickers between
    # applied (read op 2 sees 9) and dropped (read op 3 sees 7 again) —
    # the explanation must show the longest partial linearization, the
    # stuck read with expected-vs-observed values, and name the pending
    # write whose optionality was explored
    h = [
        op(1, 1, "write", 0, 7, 1, 2),
        op(4, 3, "write", 0, 9, 3, None),
        op(2, 2, "read", 0, 9, 5, 6),
        op(3, 2, "read", 0, 7, 7, 8),
    ]
    res = check_history(h)
    assert not res.ok
    assert res.partial == (1, 4, 2)
    assert res.explain() == (
        "NOT linearizable (key 0):\n"
        "  longest partial linearization: [1, 4, 2]\n"
        "  stuck frontier (minimal candidates):\n"
        "    read op 3 (client 2) returned 7, register holds 9\n"
        "    pending writes considered (applied or dropped): [4]"
    )


def test_operations_from_records_pairs_and_keeps_pending():
    from repro.core.handlers import HistoryLog

    log = HistoryLog()
    log.invoke(101, 1, "write", 0, 7)
    log.invoke(102, 2, "read", 0)
    log.respond(101, 1)
    ops = operations_from_records(log.records)
    assert {o.op_id for o in ops} == {1, 2}
    w = next(o for o in ops if o.kind == "write")
    r = next(o for o in ops if o.kind == "read")
    assert not w.pending and w.value == 7
    assert r.pending
    assert w.invoke < w.response
    assert check_records(log.records).ok


def test_checker_scales_to_contended_histories():
    # many overlapping ops on one key: the memoized search must not blow
    # up (this is the shape the harness emits)
    rng = random.Random(7)
    h, t = [], 0
    last = 0
    for i in range(1, 41):
        t += 1
        inv = t
        t += rng.randint(1, 3)
        if i % 2:
            last = i
            h.append(op(i, i % 4, "write", 0, i, inv, t))
        else:
            h.append(op(i, i % 4, "read", 0, last - 1 if last > 1 else 0,
                        inv, t))
    # verdict is not asserted (the random history may or may not be
    # linearizable); the point is termination in bounded time
    check_history(h)


# -- (b) protocol proofs over the fault grid ---------------------------------


def _workload(nclients, nops, keys, seed):
    rng = random.Random(seed)
    out = []
    for c in range(nclients):
        ops = []
        for i in range(nops):
            key = rng.choice(keys)
            if rng.random() < 0.5:
                ops.append(("write", key, (c + 1) * 10_000 + i))
            else:
                ops.append(("read", key, None))
        out.append(ops)
    return out


def _run_and_check(kind, seed, **kw) -> CheckResult:
    h = ReplicationHarness(kind, 3, seed=seed, **kw)
    for ops in _workload(3, 8, [1, 2], seed):
        h.add_client(ops)
    log = h.run()
    res = check_records(log.records)
    assert res.ok, f"{kind} seed={seed} kw={kw}:\n{res.explain()}"
    # the run must have made real progress, not vacuously passed
    assert sum(1 for r in log.records if r["ev"] == "ok") >= 12
    return res


#: crash x loss x straggler grid (node ids are 1..3)
FAULT_GRID = [
    {},
    {"crashes": ((40, 3),)},                 # tail crash -> reconfigure
    {"crashes": ((40, 1),)},                 # head crash -> new head
    {"loss": {2: 0.2}},                      # lossy middle link
    {"slow": {3: 6.0}},                      # straggler tail
    {"crashes": ((60, 2),), "loss": {1: 0.1}, "slow": {3: 4.0}},
]


@pytest.mark.parametrize("fault", FAULT_GRID[:3],
                         ids=["healthy", "crash-tail", "crash-head"])
def test_chain_linearizable(fault):
    _run_and_check("chain", seed=11, **fault)


@pytest.mark.parametrize("fault", FAULT_GRID[:3],
                         ids=["healthy", "crash-tail", "crash-head"])
def test_abd_linearizable(fault):
    _run_and_check("abd", seed=13, **fault)


def test_chain_tail_only_reads_linearizable():
    _run_and_check("chain", seed=17, dirty_read=False,
                   crashes=((50, 3),))


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["chain", "abd"])
@pytest.mark.parametrize("fault", FAULT_GRID,
                         ids=["healthy", "crash-tail", "crash-head",
                              "loss", "straggler", "combined"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_full_fault_grid_linearizable(kind, fault, seed):
    _run_and_check(kind, seed=seed, **fault)


def test_harness_from_spec_lowers_consistency():
    chain = ReplicationHarness.from_spec(
        PolicySpec("spin", SpongeAuth(),
                   consistency=Chain(k=3, dirty_read=False)))
    assert chain.kind == "chain" and not chain.dirty_read
    abd = ReplicationHarness.from_spec(
        PolicySpec("spin", SpongeAuth(), consistency=Quorum(n=5)))
    assert abd.kind == "abd" and len(abd.replicas) == 5


# -- (c) mutation test -------------------------------------------------------


def test_mutated_chain_is_flagged():
    """Skip the version bump at the tail (acks without committing): the
    checker must catch the stale reads this produces."""
    flagged = []
    for seed in range(6):
        h = ReplicationHarness("chain", 3, seed=seed, tail_bump=False)
        for ops in _workload(3, 8, [1, 2], seed):
            h.add_client(ops)
        res = check_records(h.run().records)
        if not res.ok:
            flagged.append((seed, res))
    assert flagged, "mutated protocol produced no violation in 6 seeds"
    # the counterexample is actionable: it names a stale read
    _, res = flagged[0]
    assert any("read op" in f for f in res.frontier)


def test_mutated_chain_counterexample_mentions_register_value():
    h = ReplicationHarness("chain", 3, seed=0, tail_bump=False)
    for ops in _workload(3, 8, [1, 2], 0):
        h.add_client(ops)
    res = check_records(h.run().records)
    if res.ok:  # this seed happens to pass: the grid test above covers it
        pytest.skip("seed 0 did not trip the mutation")
    assert "register holds" in res.explain()
