"""Checkpoint plane: EC/replicated save-restore, degraded mode, healing."""

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.storage import StorageCluster
from repro.core.packets import ReplStrategy, Resiliency


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer0": {"w": rng.standard_normal((64, 128)).astype(np.float32),
                   "b": np.zeros(128, np.float32)},
        "emb": rng.integers(-5, 5, (32, 16)).astype(np.int32),
        "step": np.asarray(41),
    }


def _assert_tree_equal(a, b):
    assert np.array_equal(a["layer0"]["w"], b["layer0"]["w"])
    assert np.array_equal(a["layer0"]["b"], b["layer0"]["b"])
    assert np.array_equal(a["emb"], b["emb"])
    assert a["step"] == b["step"]


def test_ec_checkpoint_survives_m_failures():
    cluster = StorageCluster(num_nodes=8, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=4, m=2,
                                                      stripe_bytes=1 << 16))
    tree = _tree()
    mgr.save(10, tree, blocking=True)
    cluster.fail_node(1)
    cluster.fail_node(6)
    _assert_tree_equal(mgr.restore(10, treedef=tree), tree)


def test_ec_checkpoint_fails_beyond_m():
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=4, m=1,
                                                      stripe_bytes=1 << 16))
    tree = _tree(1)
    mgr.save(1, tree, blocking=True)
    cluster.fail_node(0)
    cluster.fail_node(1)
    cluster.fail_node(2)  # > m failures somewhere in the stripes
    with pytest.raises((ValueError, IOError)):
        mgr.restore(1, treedef=tree)


def test_heal_rebuilds_shards():
    cluster = StorageCluster(num_nodes=8, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=4, m=2,
                                                      stripe_bytes=1 << 16))
    tree = _tree(2)
    mgr.save(5, tree, blocking=True)
    cluster.fail_node(3)
    cluster.heal_node(3)            # rebuild from survivors
    cluster.fail_node(0)
    cluster.fail_node(1)            # two NEW failures; healed node must help
    _assert_tree_equal(mgr.restore(5, treedef=tree), tree)


def test_replicated_checkpoint_failover():
    cluster = StorageCluster(num_nodes=4)
    mgr = CheckpointManager(
        cluster,
        CheckpointPolicy(resiliency=Resiliency.REPLICATION, k=3,
                         strategy=ReplStrategy.PBT, stripe_bytes=1 << 16),
    )
    tree = _tree(3)
    mgr.save(2, tree, blocking=True)
    cluster.fail_node(0)
    cluster.fail_node(1)
    _assert_tree_equal(mgr.restore(2, treedef=tree), tree)


def test_multiple_steps_latest():
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 24)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=3, m=1,
                                                      stripe_bytes=1 << 16))
    t1, t2 = _tree(10), _tree(20)
    mgr.save(1, t1, blocking=True)
    mgr.save(2, t2, blocking=True)
    assert mgr.latest_step() == 2
    _assert_tree_equal(mgr.restore(treedef=t2), t2)
    _assert_tree_equal(mgr.restore(1, treedef=t1), t1)


def test_spill_and_reload_from_disk(tmp_path):
    """Cluster contents + namespace survive a process 'restart' via spill."""
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 20,
                             spill_dir=str(tmp_path / "spill"))
    blob = np.random.default_rng(0).integers(0, 256, 50_000, dtype=np.uint8)
    layout = cluster.write_object(blob.tobytes(), k=3, m=2)
    d = cluster.spill()

    revived = StorageCluster.from_spill(d)
    got = revived.read_object(revived.meta.lookup(layout.object_id))
    assert got == blob.tobytes()
    # degraded read still works after reload
    revived.fail_node(layout.data_coords[0].node)
    revived.fail_node(layout.parity_coords[0].node)
    assert revived.read_object(revived.meta.lookup(layout.object_id)) == \
        blob.tobytes()


# -- PolicySpec-routed EC: batched client encode (RSCode.encode_stripes) ----


def test_bulk_client_encode_matches_nic_streaming_path():
    """encode='client' (one batched RSCode.encode_stripes per leaf) must
    lay out byte-identical shards to the per-packet NIC streaming path."""
    blob = np.random.default_rng(7).integers(0, 256, 70_001, dtype=np.uint8)
    a = StorageCluster(num_nodes=6, node_capacity=1 << 22)
    b = StorageCluster(num_nodes=6, node_capacity=1 << 22)
    la = a.write_object_bulk([blob.tobytes()], k=3, m=2)[0]
    lb = b.write_object(blob.tobytes(), k=3, m=2)  # NIC streaming EC
    assert la.chunk_len == lb.chunk_len
    for ca, cb in zip(
        list(la.data_coords) + list(la.parity_coords),
        list(lb.data_coords) + list(lb.parity_coords),
    ):
        sa = a.nodes[ca.node].read(ca.addr, la.chunk_len)
        sb = b.nodes[cb.node].read(cb.addr, lb.chunk_len)
        assert np.array_equal(sa, sb)
    assert a.read_object(la) == blob.tobytes()


def test_bulk_encode_roundtrip_under_erasures():
    """ROADMAP item: encode_stripes wired into checkpoint EC — the bulk
    path must survive m node losses end to end."""
    cluster = StorageCluster(num_nodes=8, node_capacity=1 << 23)
    mgr = CheckpointManager(
        cluster,
        CheckpointPolicy(k=4, m=2, stripe_bytes=1 << 15, encode="client"),
    )
    tree = _tree(9)
    mgr.save(3, tree, blocking=True)
    cluster.fail_node(2)
    cluster.fail_node(5)
    _assert_tree_equal(mgr.restore(3, treedef=tree), tree)
    # beyond m failures the stripe must be unrecoverable
    cluster.fail_node(0)
    cluster.fail_node(1)
    with pytest.raises((ValueError, IOError)):
        mgr.restore(3, treedef=tree)


def test_manager_accepts_policy_spec():
    """CheckpointManager lowers a declarative PolicySpec directly."""
    from repro.policy import PolicySpec, RS, SpongeAuth

    spec = PolicySpec("spin", SpongeAuth(), erasure=RS(3, 2, "client"))
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, spec)
    assert mgr.policy.k == 3 and mgr.policy.m == 2
    assert mgr.policy.encode == "client"
    tree = _tree(11)
    mgr.save(1, tree, blocking=True)
    cluster.fail_node(1)
    _assert_tree_equal(mgr.restore(1, treedef=tree), tree)


def test_checkpoint_policy_spec_roundtrip():
    for pol in (
        CheckpointPolicy(k=5, m=3, encode="client"),
        CheckpointPolicy(k=4, m=2, encode="nic"),
        CheckpointPolicy(resiliency=Resiliency.REPLICATION, k=3,
                         strategy=ReplStrategy.PBT),
    ):
        back = CheckpointPolicy.from_spec(pol.spec(),
                                          stripe_bytes=pol.stripe_bytes)
        assert back.resiliency == pol.resiliency
        assert back.k == pol.k
        if pol.resiliency == Resiliency.ERASURE_CODING:
            assert back.m == pol.m and back.encode == pol.encode
        else:
            assert back.strategy == pol.strategy
