"""Checkpoint plane: EC/replicated save-restore, degraded mode, healing."""

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.storage import StorageCluster
from repro.core.packets import ReplStrategy, Resiliency


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer0": {"w": rng.standard_normal((64, 128)).astype(np.float32),
                   "b": np.zeros(128, np.float32)},
        "emb": rng.integers(-5, 5, (32, 16)).astype(np.int32),
        "step": np.asarray(41),
    }


def _assert_tree_equal(a, b):
    assert np.array_equal(a["layer0"]["w"], b["layer0"]["w"])
    assert np.array_equal(a["layer0"]["b"], b["layer0"]["b"])
    assert np.array_equal(a["emb"], b["emb"])
    assert a["step"] == b["step"]


def test_ec_checkpoint_survives_m_failures():
    cluster = StorageCluster(num_nodes=8, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=4, m=2,
                                                      stripe_bytes=1 << 16))
    tree = _tree()
    mgr.save(10, tree, blocking=True)
    cluster.fail_node(1)
    cluster.fail_node(6)
    _assert_tree_equal(mgr.restore(10, treedef=tree), tree)


def test_ec_checkpoint_fails_beyond_m():
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=4, m=1,
                                                      stripe_bytes=1 << 16))
    tree = _tree(1)
    mgr.save(1, tree, blocking=True)
    cluster.fail_node(0)
    cluster.fail_node(1)
    cluster.fail_node(2)  # > m failures somewhere in the stripes
    with pytest.raises((ValueError, IOError)):
        mgr.restore(1, treedef=tree)


def test_heal_rebuilds_shards():
    cluster = StorageCluster(num_nodes=8, node_capacity=1 << 23)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=4, m=2,
                                                      stripe_bytes=1 << 16))
    tree = _tree(2)
    mgr.save(5, tree, blocking=True)
    cluster.fail_node(3)
    cluster.heal_node(3)            # rebuild from survivors
    cluster.fail_node(0)
    cluster.fail_node(1)            # two NEW failures; healed node must help
    _assert_tree_equal(mgr.restore(5, treedef=tree), tree)


def test_replicated_checkpoint_failover():
    cluster = StorageCluster(num_nodes=4)
    mgr = CheckpointManager(
        cluster,
        CheckpointPolicy(resiliency=Resiliency.REPLICATION, k=3,
                         strategy=ReplStrategy.PBT, stripe_bytes=1 << 16),
    )
    tree = _tree(3)
    mgr.save(2, tree, blocking=True)
    cluster.fail_node(0)
    cluster.fail_node(1)
    _assert_tree_equal(mgr.restore(2, treedef=tree), tree)


def test_multiple_steps_latest():
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 24)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=3, m=1,
                                                      stripe_bytes=1 << 16))
    t1, t2 = _tree(10), _tree(20)
    mgr.save(1, t1, blocking=True)
    mgr.save(2, t2, blocking=True)
    assert mgr.latest_step() == 2
    _assert_tree_equal(mgr.restore(treedef=t2), t2)
    _assert_tree_equal(mgr.restore(1, treedef=t1), t1)


def test_spill_and_reload_from_disk(tmp_path):
    """Cluster contents + namespace survive a process 'restart' via spill."""
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 20,
                             spill_dir=str(tmp_path / "spill"))
    blob = np.random.default_rng(0).integers(0, 256, 50_000, dtype=np.uint8)
    layout = cluster.write_object(blob.tobytes(), k=3, m=2)
    d = cluster.spill()

    revived = StorageCluster.from_spill(d)
    got = revived.read_object(revived.meta.lookup(layout.object_id))
    assert got == blob.tobytes()
    # degraded read still works after reload
    revived.fail_node(layout.data_coords[0].node)
    revived.fail_node(layout.parity_coords[0].node)
    assert revived.read_object(revived.meta.lookup(layout.object_id)) == \
        blob.tobytes()
