"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
used by this test suite.

When ``hypothesis`` is installed the real library is used (see the
``try: import hypothesis`` blocks in the test modules); this shim keeps the
property tests runnable — deterministically — when it is absent.  Each
strategy knows how to produce deterministic edge cases first (min/max
bounds) and then seeded pseudo-random samples, so every test still
exercises boundary values plus a spread of the input space.

Only the strategies this repo uses are implemented: ``integers``,
``booleans``, ``binary``, ``sampled_from``, ``lists``, ``randoms`` and the
``.filter`` combinator.
"""

from __future__ import annotations

import random
import zlib
from typing import Any, Callable

DEFAULT_MAX_EXAMPLES = 20
_FILTER_TRIES = 10_000


class _Strategy:
    def __init__(self, sample: Callable[[random.Random], Any], edges=()):
        self._sample = sample
        self.edges = list(edges)

    def sample(self, rnd: random.Random) -> Any:
        return self._sample(rnd)

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        def sample(rnd: random.Random) -> Any:
            for _ in range(_FILTER_TRIES):
                v = self._sample(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive for shim")

        return _Strategy(sample, [e for e in self.edges if pred(e)])

    def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(
            lambda rnd: fn(self._sample(rnd)), [fn(e) for e in self.edges]
        )


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int = -(2**63), max_value: int = 2**63) -> _Strategy:
        return _Strategy(
            lambda rnd: rnd.randint(min_value, max_value),
            [min_value, max_value],
        )

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rnd: bool(rnd.getrandbits(1)), [False, True])

    @staticmethod
    def binary(min_size: int = 0, max_size: int = 100) -> _Strategy:
        def sample(rnd: random.Random) -> bytes:
            n = rnd.randint(min_size, max_size)
            return bytes(rnd.getrandbits(8) for _ in range(n))

        pat_len = min(max_size, max(min_size, 256))
        pattern = bytes(i % 256 for i in range(pat_len))
        return _Strategy(sample, [b"\x00" * min_size, pattern])

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rnd: rnd.choice(options), options[:2])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int | None = None) -> _Strategy:
        def sample(rnd: random.Random) -> list:
            hi = max_size if max_size is not None else min_size + 10
            n = rnd.randint(min_size, hi)
            return [elements.sample(rnd) for _ in range(n)]

        return _Strategy(sample)

    @staticmethod
    def randoms(use_true_random: bool = False) -> _Strategy:
        return _Strategy(lambda rnd: random.Random(rnd.getrandbits(64)))


st = strategies


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Works whether applied above or below ``@given``."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        def wrapper():
            max_examples = getattr(
                wrapper, "_shim_max_examples",
                getattr(fn, "_shim_max_examples", DEFAULT_MAX_EXAMPLES),
            )
            rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(max_examples):
                args = []
                for s in strats:
                    if i < len(s.edges):
                        args.append(s.edges[i])
                    else:
                        args.append(s.sample(rnd))
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (shim): {fn.__name__}{tuple(args)!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
