"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle.

Every kernel runs in interpret mode (CPU executes the kernel body) and is
asserted exactly equal (integer domain) to ref.py and the numpy oracle.
The (k, m) matrix covers the paper's schemes — RS(3,2) and RS(6,3) — plus
the minimal RS(2,1); jit caching is maximized by reusing static configs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.erasure import RSCode
from repro.kernels import ops, ref
from repro.kernels.gf256_encode import (
    gf_matmul_bitsliced,
    gf_matmul_bitsliced_batched,
)
from repro.kernels.xor_reduce import xor_reduce_batched


@pytest.mark.parametrize("k,m", [(2, 1), (3, 2), (6, 3)])
@pytest.mark.parametrize("length", [100, 1024])
def test_rs_encode_pallas_matches_numpy(k, m, length):
    rng = np.random.default_rng(k * 100 + m)
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    want = RSCode(k, m).encode(data)
    got = np.asarray(ops.rs_encode(jnp.asarray(data), k, m, block_w=8))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("block_w", [8, 32])
def test_rs_encode_block_shape_sweep(block_w):
    k, m = 3, 2
    rng = np.random.default_rng(block_w)
    data = rng.integers(0, 256, (k, 32 * block_w * 2), dtype=np.uint8)
    want = RSCode(k, m).encode(data)
    got = np.asarray(ops.rs_encode(jnp.asarray(data), k, m, block_w=block_w))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("k,m", [(3, 2), (6, 3)])
def test_rs_encode_mxu_variant(k, m):
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, (k, 1000), dtype=np.uint8)
    want = RSCode(k, m).encode(data)
    got = np.asarray(ops.rs_encode_mxu(jnp.asarray(data), k, m, block_n=128))
    assert np.array_equal(got, want)


def test_bitsliced_kernel_matches_bitsliced_ref():
    from repro.core import gf256

    k, m, w = 3, 2, 32
    rng = np.random.default_rng(0)
    parity = gf256.cauchy_parity_matrix(k, m)
    bitmat = jnp.asarray(gf256.parity_bitmatrix(parity), jnp.uint32)
    planes = jnp.asarray(rng.integers(0, 2**32, (k, 8, w), dtype=np.uint32))
    got = gf_matmul_bitsliced(bitmat, planes, m=m, k=k, block_w=8)
    want = ref.gf_matmul_bitsliced_ref(bitmat, planes)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("s", [1, 3])
def test_bitsliced_batched_kernel_matches_ref(s):
    """The 2D (stripe, word-block) grid equals the unbatched oracle
    applied per stripe — at the raw bit-plane level."""
    from repro.core import gf256

    k, m, w = 3, 2, 32
    rng = np.random.default_rng(s)
    parity = gf256.cauchy_parity_matrix(k, m)
    bitmat = jnp.asarray(gf256.parity_bitmatrix(parity), jnp.uint32)
    planes = jnp.asarray(rng.integers(0, 2**32, (s, k, 8, w), dtype=np.uint32))
    got = gf_matmul_bitsliced_batched(bitmat, planes, m=m, k=k, block_w=8)
    want = np.stack([
        np.asarray(ref.gf_matmul_bitsliced_ref(bitmat, planes[i]))
        for i in range(s)
    ])
    assert np.array_equal(np.asarray(got), want)


def test_xor_reduce_batched_kernel():
    rng = np.random.default_rng(2)
    x = rng.integers(0, 2**32, (3, 5, 16), dtype=np.uint32)
    got = np.asarray(xor_reduce_batched(jnp.asarray(x), block_w=8))
    assert np.array_equal(got, np.bitwise_xor.reduce(x, axis=1))


def test_decode_path_via_kernel():
    code = RSCode(3, 2)
    rng = np.random.default_rng(1)
    data = rng.integers(0, 256, (3, 500), dtype=np.uint8)
    parity = code.encode(data)
    shards = [None, data[1], None, parity[0], parity[1]]
    got = code.decode(shards, backend="jax")
    assert np.array_equal(got, data)


@pytest.mark.parametrize("n", [2, 5])
@pytest.mark.parametrize("length", [64, 1000])
def test_xor_reduce(n, length):
    rng = np.random.default_rng(n * length)
    x = rng.integers(0, 256, (n, length), dtype=np.uint8)
    want = x[0].copy()
    for i in range(1, n):
        want ^= x[i]
    got = np.asarray(ops.xor_reduce_bytes(jnp.asarray(x)))
    assert np.array_equal(got, want)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=500))
def test_rs_encode_property_lengths(length):
    """Arbitrary (unaligned) lengths agree with the oracle (RS(3,2) fixed
    so the jitted kernel is compiled once)."""
    rng = np.random.default_rng(length)
    data = rng.integers(0, 256, (3, length), dtype=np.uint8)
    want = RSCode(3, 2).encode(data)
    got = np.asarray(ops.rs_encode(jnp.asarray(data), 3, 2, block_w=8))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("hkv,causal,bq,bk", [
    (2, True, 16, 32), (4, True, 32, 32), (1, False, 32, 64),
])
def test_pallas_flash_attention_matches_reference(hkv, causal, bq, bk):
    from repro.kernels.flash_attention import flash_attention_fwd

    b, s, h, d = 2, 64, 4, 16
    rng = np.random.default_rng(hkv * bq)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk)
    # reference: the (independently validated) jnp blockwise path
    from repro.models.attention import blockwise_attention

    want = blockwise_attention(q, k, v, causal, 32, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_ragged_seq_padding():
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.attention import blockwise_attention

    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 50, 6, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 50, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 50, 2, 8)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=True, bq=16, bk=16)
    want = blockwise_attention(q, k, v, True, 16, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
