"""SizeDist property tests: empirical-mean bounds + seed determinism.

Until now ``SizeDist`` was only exercised indirectly through mixed
scenarios; these pin its contract directly:

(a) lognormal — the mu-correction makes the *empirical* mean track the
    configured ``mean`` (within sampling tolerance) across means and
    sigmas, and every draw respects [min_bytes, max_bytes]
(b) bimodal — draws take exactly the two configured values and the
    empirical large-fraction tracks ``p_large``
(c) fixed — always exactly ``mean``
(d) determinism — equal seeds give identical draw sequences, different
    seeds diverge (the workload engine's reproducibility rests on this)
"""

import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic dependency-free fallback
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.sim.workload import KiB, SizeDist

N = 4000


def _samples(dist, seed=0, n=N):
    rnd = random.Random(seed)
    return [dist.sample(rnd) for _ in range(n)]


# -- (a) lognormal -----------------------------------------------------------


@settings(max_examples=18, deadline=None)
@given(
    st.sampled_from([16, 64, 256]),            # mean (KiB)
    st.sampled_from([0.25, 0.6, 1.0]),         # sigma
    st.integers(min_value=0, max_value=2**31),  # sample seed
)
def test_lognormal_empirical_mean_tracks_config(mean_kib, sigma, seed):
    mean = mean_kib * KiB
    dist = SizeDist("lognormal", mean=mean, sigma=sigma,
                    max_bytes=64 << 20)  # keep the tail unclamped
    xs = _samples(dist, seed)
    emp = sum(xs) / len(xs)
    # the mu = log(mean) - sigma^2/2 correction keeps the expectation at
    # ``mean``; at sigma=1.0 the heavy tail needs the widest band
    assert 0.8 * mean <= emp <= 1.25 * mean


def test_lognormal_respects_bounds():
    dist = SizeDist("lognormal", mean=64 * KiB, sigma=2.0,
                    min_bytes=1024, max_bytes=128 * KiB)
    xs = _samples(dist, seed=9)
    assert min(xs) >= 1024
    assert max(xs) <= 128 * KiB
    # a sigma this heavy actually exercises both clamps
    assert 1024 in xs and 128 * KiB in xs


# -- (b) bimodal -------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(
    st.sampled_from([0.05, 0.125, 0.5, 0.9]),  # p_large
    st.integers(min_value=0, max_value=2**31),  # sample seed
)
def test_bimodal_mixture_fraction(p_large, seed):
    dist = SizeDist("bimodal", small=4 * KiB, large=256 * KiB,
                    p_large=p_large)
    xs = _samples(dist, seed)
    assert set(xs) <= {4 * KiB, 256 * KiB}
    frac = sum(x == 256 * KiB for x in xs) / len(xs)
    assert abs(frac - p_large) < 0.04
    emp = sum(xs) / len(xs)
    want = p_large * 256 * KiB + (1 - p_large) * 4 * KiB
    assert 0.85 * want <= emp <= 1.15 * want


# -- (c) fixed ---------------------------------------------------------------


def test_fixed_is_exact():
    dist = SizeDist("fixed", mean=96 * KiB)
    assert set(_samples(dist, seed=1, n=64)) == {96 * KiB}


def test_unknown_kind_raises():
    with pytest.raises(ValueError):
        SizeDist("zipf").sample(random.Random(0))


# -- (d) seed determinism ----------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(
    st.sampled_from(["lognormal", "bimodal"]),
    st.integers(min_value=0, max_value=2**31),  # shared seed
)
def test_same_seed_same_draws(kind, seed):
    dist = SizeDist(kind, mean=64 * KiB)
    assert _samples(dist, seed, n=256) == _samples(dist, seed, n=256)


def test_different_seeds_diverge():
    dist = SizeDist("lognormal", mean=64 * KiB)
    assert _samples(dist, 0, n=256) != _samples(dist, 1, n=256)
