"""Metadata plane (PR 8): namespace tree + extent maps, placement-policy
invariants (property-tested), the detected-view re-replication loop, and
the timed metadata RPC pipelines (NIC handler vs host CPU)."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.checkpoint.storage import StorageCluster
from repro.control.governor import RepairPacer
from repro.membership import MembershipConfig
from repro.namenode import (
    BlockReplicator,
    FailureDomainPlacement,
    FileNode,
    LoadBalancedPlacement,
    NameNode,
    Namespace,
    RoundRobinPlacement,
)
from repro.policy import (
    Flat,
    HostAuth,
    NoAuth,
    PRESET_NAMES,
    PolicySpec,
    SpongeAuth,
    preset_spec,
)
from repro.policy.timed import compile_policy
from repro.sim import protocols as P

pytestmark = pytest.mark.namespace


# -- namespace tree + extent map ------------------------------------------------


def test_namespace_tree_ops():
    ns = Namespace()
    ns.mkdir("/a/b/c")                       # mkdir -p
    assert ns.num_dirs == 4                  # root + a + b + c
    f = ns.create("/a/b/f", replication=2)
    assert isinstance(f, FileNode) and f.replication == 2
    assert ns.lookup("/a/b/f") is f
    assert ns.listdir("/a/b") == ["c", "f"]
    with pytest.raises(FileExistsError):
        ns.create("/a/b/f")
    with pytest.raises(FileNotFoundError):
        ns.lookup("/a/b/missing")
    with pytest.raises(NotADirectoryError):
        ns.listdir("/a/b/f")
    with pytest.raises(ValueError):
        ns.lookup("relative/path")
    ns.delete("/a/b/f")
    assert ns.num_files == 0
    with pytest.raises(FileNotFoundError):
        ns.lookup("/a/b/f")


def test_extent_map_generation_stamps():
    ns = Namespace()
    f = ns.create("/f")
    b1 = ns.commit_block(f, 4096, [0, 1, 2], object_id=7)
    b2 = ns.commit_block(f, 2048, [3, 4, 5])
    assert b2.gen_stamp > b1.gen_stamp       # stamps are monotonic
    assert f.size == 6144 and ns.num_blocks == 2
    assert b1.replicas_on({1, 2, 9}) == 2
    old = b2.gen_stamp
    ns.repoint(b2, 4, 0)                     # re-replication fences 4's copy
    assert b2.placements == [3, 0, 5]
    assert b2.gen_stamp > old
    with pytest.raises(ValueError):
        ns.commit_block(f, 0, [0])


# -- placement invariants (property-tested) -------------------------------------


@settings(max_examples=30)
@given(st.integers(2, 10), st.integers(0, 2), st.integers(1, 3), st.randoms())
def test_placement_never_uses_excluded_nodes(live, nexcl, n, rnd):
    """No policy ever places on an excluded (failed/suspected) node, and
    the chosen nodes are distinct."""
    num = live + nexcl
    n = min(n, live)
    excl = set(rnd.sample(range(num), nexcl))
    for pol in (RoundRobinPlacement(num), LoadBalancedPlacement(num),
                FailureDomainPlacement(num, [v % 2 for v in range(num)])):
        for _ in range(8):
            chosen = pol.place(n, exclude=excl)
            assert len(chosen) == n
            assert len(set(chosen)) == n
            assert not set(chosen) & excl
            for v in chosen:
                pol.record(v, 4096)


def test_placement_insufficient_live_raises():
    for pol in (RoundRobinPlacement(4), LoadBalancedPlacement(4),
                FailureDomainPlacement(4, [0, 0, 1, 1])):
        with pytest.raises(RuntimeError):
            pol.place(3, exclude={0, 1})


def test_round_robin_unbiased_under_exclusion():
    """The satellite bug fix: with node 1 down on a 5-node ring, the four
    survivors each take the lead slot equally (the old cursor skewed the
    failed node's successor)."""
    pol = RoundRobinPlacement(5)
    lead = [0] * 5
    for _ in range(40):
        lead[pol.place(2, exclude={1})[0]] += 1
    assert lead[1] == 0
    assert all(c == 10 for i, c in enumerate(lead) if i != 1)


@settings(max_examples=25)
@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 2), st.randoms())
def test_failure_domains_distinct_when_enough_live(ndom, per_dom, nexcl, rnd):
    """No two shards share a failure domain whenever the stripe fits in
    the live domains (domains >= n)."""
    num = ndom * per_dom
    dom_of = [v // per_dom for v in range(num)]
    pol = FailureDomainPlacement(num, dom_of)
    excl = set(rnd.sample(range(num), min(nexcl, num - 1)))
    n = min(pol.domains_live(excl), num - len(excl))
    for _ in range(6):
        chosen = pol.place(n, exclude=excl)
        assert len({dom_of[v] for v in chosen}) == n


def test_failure_domain_overflow_cap():
    """With fewer domains than shards the per-domain overflow stays
    minimal: ceil(n/domains) shards at most in any one domain."""
    pol = FailureDomainPlacement(6, [0, 0, 1, 1, 2, 2])
    chosen = pol.place(5)
    per_dom = [sum(1 for v in chosen if v // 2 == d) for d in range(3)]
    assert max(per_dom) == 2                 # ceil(5/3)


@settings(max_examples=25)
@given(st.integers(3, 8),
       st.lists(st.integers(1, 1000), min_size=1, max_size=50))
def test_load_balanced_spread_bounded(num_nodes, sizes):
    """Greedy least-loaded keeps the max-min byte spread within the
    largest single extent."""
    pol = LoadBalancedPlacement(num_nodes)
    for s in sizes:
        pol.record(pol.place(1)[0], s)
    assert max(pol.loads) - min(pol.loads) <= max(sizes)


# -- StorageCluster integration -------------------------------------------------


def test_cluster_consults_injected_policy():
    from repro.core.packets import Resiliency

    pol = LoadBalancedPlacement(4)
    c = StorageCluster(4, node_capacity=1 << 20, placement=pol)
    assert c.meta.placement is pol
    for _ in range(8):
        c.write_object(b"x" * 4096, resiliency=Resiliency.REPLICATION, k=2)
    # the allocator feeds the policy's ledger; greedy keeps it level
    assert max(pol.loads) - min(pol.loads) <= 4096


def test_suspected_nodes_never_placed():
    from repro.core.packets import Resiliency

    c = StorageCluster(4, node_capacity=1 << 20)
    c.meta.suspected.add(1)                  # detected-dead, not omniscient
    for _ in range(6):
        layout = c.write_object(b"y" * 2048,
                                resiliency=Resiliency.REPLICATION, k=2)
        assert all(coord.node != 1 for coord in layout.data_coords)


# -- re-replication --------------------------------------------------------------


def test_replicator_bookkeeping_only():
    """Clusterless drain: repoints extent maps, accounts the policy
    ledger, and flags unrecoverable blocks (all replicas dead)."""
    ns = Namespace()
    f = ns.create("/f")
    b_ok = ns.commit_block(f, 4096, [0, 1, 2])
    b_gone = ns.commit_block(f, 4096, [3, 4])
    rep = BlockReplicator(ns, RoundRobinPlacement(6))
    assert rep.mark_dead({3, 4}) == 1
    assert rep.mark_dead({2}) == 1           # second view change, no dup
    stats = rep.run()
    assert stats["unrecoverable"] == 1       # b_gone lost both replicas
    assert stats["blocks"] == 1
    assert 2 not in b_ok.placements and len(set(b_ok.placements)) == 3
    assert b_gone.placements == [3, 4]       # left as-is, counted lost


def test_rereplication_on_detected_view_change():
    """Satellite 3: crash a datanode via the heartbeat path only — the
    lease-gated view change (never an omniscient crash() call) marks its
    blocks under-replicated; re-replication restores target replication
    within the pacer budget and the conservation audit shows zero loss."""
    clk = {"t": 0.0}
    rate_MBps = 2.0
    pacer = RepairPacer(rate_MBps, burst_bytes=8192,
                        clock=lambda: clk["t"],
                        sleep=lambda s: clk.__setitem__("t", clk["t"] + s))
    cluster = StorageCluster(6, node_capacity=1 << 20)
    nn = NameNode(cluster, cfg=MembershipConfig(interval=10.0), pacer=pacer)
    nn.mkdir("/a")
    nn.create("/a/f", replication=3)
    blocks = [nn.add_block("/a/f", bytes([i + 1]) * 4096) for i in range(8)]
    assert nn.rpc_counts() == {"lookups": 0, "opens": 2, "commits": 8}

    t, crash_at = 0.0, 200.0
    while t < 1500.0 and nn.under_replicated() == 0:
        for v in range(6):
            if not (v == 2 and t >= crash_at):   # node 2 goes silent
                nn.heartbeat(v, t)
        if t >= crash_at and 2 not in cluster.failed:
            cluster.fail_node(2)                 # makes the silence real
        nn.tick(t)
        t += 10.0

    assert nn.under_replicated() > 0             # detected via heartbeats
    assert 2 in cluster.meta.suspected           # steers new placements
    assert 2 not in nn.views.alive()
    stats = nn.re_replicate()
    assert stats["blocks"] > 0 and stats["unrecoverable"] == 0
    # pacer budget: total wait served cannot exceed bytes at the rate
    assert clk["t"] <= stats["bytes"] / (rate_MBps * 1e6) + 1e-9
    assert nn.under_replicated() == 0
    for i, b in enumerate(blocks):
        assert len(b.placements) == 3 and 2 not in b.placements
        assert nn.read_block(b) == bytes([i + 1]) * 4096
    assert cluster.audit()["lost_bytes"] == 0


# -- timed metadata pipelines ----------------------------------------------------

NS_PAIRS = (("ns-lookup-spin", "ns-lookup-host"),
            ("ns-open-spin", "ns-open-host"),
            ("ns-commit-spin", "ns-commit-host"))


def _one_shot(name):
    env = P.Env()
    proto = compile_policy(env, preset_spec(name), 0)
    out = {}
    proto.issue(P.CLIENT, on_done=lambda r: out.update(lat=r.latency_ns))
    env.sim.run()
    return out["lat"], env


def test_ns_presets_compile_and_complete():
    for spin_name, host_name in NS_PAIRS:
        assert spin_name in PRESET_NAMES and host_name in PRESET_NAMES
        spin_lat, _ = _one_shot(spin_name)
        host_lat, _ = _one_shot(host_name)
        assert 0 < spin_lat < host_lat       # PCIe detour costs the host path


def test_ns_wire_bytes_are_control_traffic():
    """Satellite 6: metadata RPC bytes ride the ctrl_* counters and never
    leak into the data-plane goodput accounting."""
    _, env = _one_shot("ns-lookup-spin")
    assert env.net.ctrl_packets_sent == 2    # request + reply
    assert env.net.ctrl_bytes_sent == 216    # (28+64) + 124
    assert env.net.packets_sent == 0         # no data packets at all


def test_metadata_spec_validation():
    with pytest.raises(ValueError, match="no replication"):
        PolicySpec("spin", SpongeAuth(), op="lookup", replication=Flat(2))
    with pytest.raises(ValueError, match="not.*raw rdma"):
        PolicySpec("rdma", NoAuth(), op="commit")
    # the transport<->auth pairing still holds for metadata ops
    with pytest.raises(ValueError):
        PolicySpec("spin", HostAuth(), op="lookup")
    assert preset_spec("ns-open-host").op == "open"
