"""Functional DFS integration: Listing-1 handlers end to end."""

import dataclasses

import numpy as np
import pytest

from repro.core.auth import CapabilityAuthority, Rights
from repro.core.erasure import RSCode, split_stripe
from repro.core.handlers import DFSClient, DFSNode, Router
from repro.core.packets import (
    DFSHeader,
    OpType,
    ReplicaCoord,
    ReplStrategy,
    Resiliency,
    WriteRequestHeader,
    packetize_write,
)


@pytest.fixture
def cluster():
    auth = CapabilityAuthority(b"0123456789abcdef")
    router = Router()
    nodes = [DFSNode(i, router, auth) for i in range(8)]
    client = DFSClient(client_id=5, router=router)
    cap = auth.issue(client_id=5, object_id=1, offset=0, length=1 << 24,
                     rights=Rights.WRITE, expiry=10**10)
    return auth, router, nodes, client, cap


def test_raw_write_lands_and_acks(cluster):
    _, router, nodes, client, cap = cluster
    data = np.random.default_rng(0).integers(0, 256, 5000, dtype=np.uint8)
    greqs = client.write(cap, data, [ReplicaCoord(0, 1000)])
    acks = client.acks()
    assert len(acks) == 1 and acks[0].ctrl == OpType.WRITE_ACK
    assert acks[0].greq_id == greqs[0]
    assert np.array_equal(nodes[0].read(1000, 5000), data)


@pytest.mark.parametrize("strategy,k", [
    (ReplStrategy.RING, 2), (ReplStrategy.RING, 4),
    (ReplStrategy.PBT, 5), (ReplStrategy.PBT, 7),
])
def test_replication_all_replicas_durable(cluster, strategy, k):
    _, router, nodes, client, cap = cluster
    data = np.random.default_rng(1).integers(0, 256, 9000, dtype=np.uint8)
    targets = [ReplicaCoord(i, 2000) for i in range(k)]
    client.write(cap, data, targets, resiliency=Resiliency.REPLICATION,
                 strategy=strategy)
    acks = client.acks()
    # durable ack: exactly one, sent only after every replica holds the data
    assert len(acks) == 1 and acks[0].ctrl == OpType.WRITE_ACK
    for i in range(k):
        assert np.array_equal(nodes[i].read(2000, 9000), data), f"replica {i}"


def test_erasure_coded_write_parities_and_decode(cluster):
    _, router, nodes, client, cap = cluster
    data = np.random.default_rng(2).integers(0, 256, 10000, dtype=np.uint8)
    dtargets = [ReplicaCoord(i, 60000) for i in range(3)]
    ptargets = [ReplicaCoord(3, 60000), ReplicaCoord(4, 60000)]
    greqs = client.write(cap, data, dtargets,
                         resiliency=Resiliency.ERASURE_CODING, ec_m=2,
                         parity_targets=ptargets)
    acks = client.acks()
    assert len(acks) == 5                    # 3 data + 2 parity(stripe) acks
    assert len([a for a in acks if a.greq_id == greqs[0]]) == 2
    code = RSCode(3, 2)
    chunks = split_stripe(data, 3)
    L = chunks.shape[1]
    assert np.array_equal(
        np.stack([nodes[i].read(60000, L) for i in range(3)]), chunks
    )
    parity = code.encode(chunks)
    for i in range(2):
        assert np.array_equal(nodes[3 + i].read(60000, L), parity[i])
    # stripe survives any 2 losses
    rec = code.decode([None, chunks[1], None, parity[0], parity[1]])
    assert np.array_equal(rec, chunks)


def test_forged_capability_nacked_no_write(cluster):
    _, router, nodes, client, cap = cluster
    bad = dataclasses.replace(cap, rights=int(Rights.ADMIN | Rights.WRITE))
    before = nodes[6].storage.bytes_written
    data = np.zeros(100, np.uint8)
    client.write(bad, data, [ReplicaCoord(6, 0)])
    acks = client.acks()
    assert acks[-1].ctrl == OpType.NACK
    assert nodes[6].storage.bytes_written == before


def test_req_table_deny_on_full(cluster):
    auth, router, nodes, client, cap = cluster
    small = DFSNode(99, router, auth, req_table_capacity=0)
    client.write(cap, np.zeros(10, np.uint8), [ReplicaCoord(99, 0)])
    assert client.acks()[-1].ctrl == OpType.NACK
    assert small.req_table.denied == 1


def test_cleanup_handler_reclaims_dangling_state(cluster):
    auth, router, nodes, client, cap = cluster
    node = DFSNode(50, router, auth)
    dfs = DFSHeader(OpType.WRITE, 777, 5, cap)
    pkts = packetize_write(dfs, WriteRequestHeader(addr=0, size=5000),
                           np.zeros(5000, np.uint8))
    node.handle_packet(pkts[0])          # header only; client then "dies"
    assert len(node.req_table) == 1
    node.cleanup_stale(alive=set())
    assert len(node.req_table) == 0 and 777 not in node._reqs
