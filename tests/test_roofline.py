"""Roofline HLO analyzer: trip counts, dot FLOPs, collective accounting."""

import textwrap

from repro.launch.roofline import HW, Roofline, analyze_hlo

HLO = textwrap.dedent(
    """
    HloModule test

    %body (p: (s32[], f32[4,32])) -> (s32[], f32[4,32]) {
      %p = (s32[], f32[4,32]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[4,32]{1,0} get-tuple-element(%p), index=1
      %w = f32[32,32]{1,0} constant({...})
      %ag = f32[4,64]{1,0} all-gather(%x), channel_id=1, dimensions={1}
      %dot = f32[4,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[4,32]) tuple(%i2, %dot)
    }

    %cond (p2: (s32[], f32[4,32])) -> pred[] {
      %p2 = (s32[], f32[4,32]) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i3, %n), direction=LT
    }

    ENTRY %main (a: f32[4,32]) -> f32[4,32] {
      %a = f32[4,32]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[4,32]) tuple(%zero, %a)
      %w1 = (s32[], f32[4,32]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      %out = f32[4,32]{1,0} get-tuple-element(%w1), index=1
      %ar = f32[4,32]{1,0} all-reduce(%out), channel_id=2, to_apply=%cond
      ROOT %r = f32[4,32]{1,0} copy(%ar)
    }
    """
)


def test_while_trip_count_and_dot_flops():
    ana = analyze_hlo(HLO)
    # dot: 2 * (4*32 out) * 32 contraction = 8192 flops x 5 iterations
    assert ana.flops_per_chip == 2 * 4 * 32 * 32 * 5
    assert ana.max_loop_mult == 5


def test_collective_accounting():
    ana = analyze_hlo(HLO)
    # all-gather inside the loop: 4*64*4B = 1024 B x 5; all-reduce outside:
    # 4*32*4 = 512 B x2 (RS+AG phases)
    assert ana.collectives["all-gather"] == 1024 * 5
    assert ana.collectives["all-reduce"] == 512 * 2
    assert ana.collective_counts["all-gather"] == 5
    assert ana.collective_counts["all-reduce"] == 1


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops_per_chip=HW["peak_flops"],       # 1 s of compute
        hbm_bytes=HW["hbm_Bps"] / 2,           # 0.5 s of memory
        collective_bytes=HW["ici_link_Bps"] * 2,  # 2 s of collectives
        chips=256,
        model_flops=HW["peak_flops"] * 256 / 2,  # 0.5 s ideal
        collectives={},
    )
    assert r.bottleneck == "collective"
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert abs(r.roofline_fraction - 0.25) < 1e-9
    assert abs(r.useful_flop_ratio - 0.5) < 1e-9


def test_dus_counted_at_update_bytes():
    hlo = textwrap.dedent(
        """
        HloModule dus

        ENTRY %main (a: f32[1024,1024], u: f32[1,1024]) -> f32[1024,1024] {
          %a = f32[1024,1024]{1,0} parameter(0)
          %u = f32[1,1024]{1,0} parameter(1)
          %z = s32[] constant(0)
          ROOT %d = f32[1024,1024]{1,0} dynamic-update-slice(%a, %u, %z, %z)
        }
        """
    )
    ana = analyze_hlo(hlo)
    # 2x the 4 KiB update, NOT 2x the 4 MiB buffer (in-place aliasing)
    assert ana.hbm_bytes_per_chip == 2 * 1024 * 4
