"""Observability suite: tracer, counter registry, exporters, attribution.

The load-bearing properties, in order of importance:

* **passivity** — attaching a tracer leaves the simulated run bit-
  identical (the tracer records intervals the model already computed;
  it never schedules events);
* **sampling** — unsampled requests allocate nothing, and the span
  buffer is bounded (overflow counts ``dropped`` instead of growing);
* **physical sanity** — spans on a serial resource's service track
  never overlap (a SerialResource admits one service at a time; queue
  waits live on their own ``... (queue)`` track);
* **stable export** — the Chrome/Perfetto document is deterministic and
  matches the committed golden byte-for-byte;
* **attribution** — the spin-vs-host write edge is explained by the
  PCIe + host-CPU spans the NIC path removed.
"""

import collections
import json
import os

import pytest

from repro.control.telemetry import Telemetry
from repro.sim.engine import EventBudgetExceeded
from repro.sim.workload import Scenario, Workload
from repro.trace import (
    BUCKETS,
    CounterRegistry,
    Tracer,
    attr,
    to_chrome_trace,
    write_chrome_trace,
)

pytestmark = pytest.mark.trace

KiB = 1024
DATA = os.path.join(os.path.dirname(__file__), "data")

#: resource-name suffixes whose (service) tracks are strictly serial —
#: one SerialResource each.  HPU pools (``nX.hpus``), links, PCIe lanes,
#: client tracks, plain-delay host detours (``nX.host``) and the flight
#: lane's coarse analytic tracks all legitimately overlap.
SERIAL_SUFFIXES = (".egress", ".ingress", ".cpu", ".inec", ".inec_pcie")


def _traced(protocol: str, sample_every: int = 1, **kw) -> tuple[Tracer, dict]:
    tr = Tracer(sample_every=sample_every)
    sc = Scenario(protocol=protocol, size=kw.pop("size", 64 * KiB),
                  num_clients=kw.pop("num_clients", 3),
                  requests_per_client=kw.pop("requests_per_client", 3),
                  k=3, m=2, seed=kw.pop("seed", 7), **kw)
    rep = sc.run(tracer=tr)
    return tr, rep


# -- tracer unit behavior --------------------------------------------------


def test_sampling_rule():
    tr = Tracer(sample_every=4)
    assert tr.sampled(0) and tr.sampled(4)
    assert not tr.sampled(1) and not tr.sampled(None)
    assert Tracer(sample_every=1).sampled(3)
    with pytest.raises(ValueError):
        Tracer(sample_every=0)


def test_span_buffer_bounded():
    tr = Tracer(sample_every=1, max_spans=10)
    sc = Scenario(protocol="spin-write", size=64 * KiB, num_clients=2,
                  requests_per_client=3, seed=7)
    sc.run(tracer=tr)
    assert len(tr) == 10
    assert tr.dropped > 0
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_sampled_out_requests_allocate_nothing():
    """Head-based sampling: every span belongs to a sampled rid, so a
    huge ``sample_every`` keeps only rid 0's spans (rids start at 0)."""
    tr, rep = _traced("spin-write", sample_every=997, num_clients=2,
                      requests_per_client=3)
    assert rep["completed"] == 6
    assert {s.rid for s in tr.spans if s.rid is not None} <= {0}
    full, _ = _traced("spin-write", sample_every=1, num_clients=2,
                      requests_per_client=3)
    assert 0 < len(tr) < len(full) / 3


# -- passivity: tracing must observe, never perturb ------------------------


@pytest.mark.parametrize("protocol", ["spin-write", "spin-triec",
                                      "abd-spin-write", "inec-triec"])
def test_tracing_leaves_report_bit_identical(protocol):
    sc = Scenario(protocol=protocol, size=64 * KiB, num_clients=2,
                  requests_per_client=3, k=3, m=2, seed=11)
    ref = sc.run()
    got = sc.run(tracer=Tracer(sample_every=4))
    got = {k: v for k, v in got.items()
           if k not in ("trace_spans", "trace_dropped")}
    assert got == ref, protocol


# -- physical sanity: serial service tracks never overlap ------------------


@pytest.mark.parametrize("protocol", ["spin-triec", "rpc-write",
                                      "inec-triec", "chain-spin-write"])
def test_serial_service_spans_never_overlap(protocol):
    tr, _ = _traced(protocol)
    tracks: dict[str, list] = collections.defaultdict(list)
    for s in tr.spans:
        res = s.resource or ""
        if res.endswith("(queue)"):
            assert s.args and s.args.get("queue"), (
                "queue track span missing its queue tag")
            continue
        if res.endswith(SERIAL_SUFFIXES):
            tracks[res].append(s)
    assert tracks, f"{protocol}: no serial-resource spans recorded"
    for res, spans in tracks.items():
        spans.sort(key=lambda s: (s.t0, s.t1))
        for a, b in zip(spans, spans[1:]):
            assert a.t1 <= b.t0 + 1e-6, (
                f"{res}: [{a.t0}, {a.t1}) overlaps [{b.t0}, {b.t1})")


def test_flight_lane_spans_are_marked_analytic():
    """The hybrid/flight lane must stay honest: its coarse spans carry
    the ``analytic`` tag on dedicated ``flight.*`` tracks."""
    tr = Tracer(sample_every=1)
    sc = Scenario(protocol="spin-triec", size=512 * KiB, num_clients=4,
                  requests_per_client=4, k=3, m=2, seed=7)
    rep = sc.run(engine="batched", tracer=tr)
    flight = [s for s in tr.spans
              if (s.resource or "").startswith("flight.")]
    assert flight, "flight lane recorded no spans"
    assert all(s.args and s.args.get("analytic") for s in flight)
    assert rep["completed"] == 16


# -- counter registry ------------------------------------------------------


def test_registry_snapshot_and_diff():
    sc = Scenario(protocol="spin-write", size=32 * KiB, num_clients=2,
                  requests_per_client=2, seed=3)
    w = Workload(sc, None, None)
    before = w.registry.snapshot()
    rep = w.run()
    after = w.registry.snapshot()
    assert rep["counters"] == after
    assert set(w.registry.names()) == set(after)
    delta = CounterRegistry.diff(before, after)
    assert delta["metrics.completed"] == 4
    assert delta["net.packets_sent"] > 0
    assert delta["sim.events"] == rep["events"]
    assert list(after) == sorted(after), "snapshot keys must be sorted"


def test_event_budget_error_carries_counters():
    sc = Scenario(protocol="spin-write", size=8 * KiB, num_clients=1,
                  requests_per_client=1, seed=1)
    for engine in ("discrete", "batched"):
        w = Workload(sc, None, None, engine=engine)
        sim = w.env.sim

        def tick():
            sim.at(sim.now + 1.0, tick)

        sim.at(0.0, tick)
        with pytest.raises(EventBudgetExceeded) as ei:
            sim.run(max_events=100)
        err = ei.value
        assert "event budget exceeded (livelock?)" in str(err)
        assert err.events > 100 and err.pending > 0
        assert err.counters is not None
        assert err.counters["sim.events"] == err.events
        assert "net.packets_sent" in str(err)


# -- telemetry per-policy split --------------------------------------------


def test_telemetry_summary_per_policy_split():
    tel = Telemetry(window_ns=20_000)
    sc = Scenario(protocol="spin-write", size=32 * KiB, num_clients=2,
                  requests_per_client=3, seed=5)
    rep = sc.run(telemetry=tel)
    s = tel.summary(warmup_frac=0.0)
    assert set(s["per_policy"]) == {"spin-write"}
    pp = s["per_policy"]["spin-write"]
    assert pp["completed"] == rep["completed"] == 6
    assert pp["goodput_GBps"] > 0
    assert pp["p99_ns"] > 0
    assert Telemetry().summary()["per_policy"] == {}


# -- exporters -------------------------------------------------------------


def _golden_tracer() -> Tracer:
    tr = Tracer(sample_every=1)
    Scenario(protocol="spin-write", size=8 * KiB, num_clients=1,
             requests_per_client=1, seed=1).run(tracer=tr)
    return tr


def test_perfetto_golden_roundtrip(tmp_path):
    tr = _golden_tracer()
    with open(os.path.join(DATA, "trace_golden.json")) as f:
        golden = json.load(f)
    assert to_chrome_trace(tr) == golden
    out = tmp_path / "trace.json"
    doc = write_chrome_trace(tr, str(out))
    assert json.loads(out.read_text()) == golden == doc


def test_perfetto_document_shape():
    tr = _golden_tracer()
    doc = to_chrome_trace(tr)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] == "X"]
    assert len(spans) == len(tr)
    names = {e["args"]["name"] for e in meta if e["name"] == "process_name"}
    assert "spin-write" in names, "request roots grouped under the policy"
    for e in spans:
        assert e["dur"] >= 0 and e["pid"] >= 1 and e["tid"] >= 1
        assert e["cat"] in (*BUCKETS, "request")


# -- attribution -----------------------------------------------------------


def test_attribution_explains_spin_vs_host_edge():
    tr_host, _ = _traced("rpc-write", num_clients=2)
    tr_nic, _ = _traced("spin-write", num_clients=2)
    host = attr.per_policy(tr_host)["rpc-write"]
    nic = attr.per_policy(tr_nic)["spin-write"]
    # the NIC path removes the PCIe + host-CPU hops entirely...
    assert host["pcie"] > 0 and host["host_cpu"] > 0
    assert nic["pcie"] == 0 and nic["host_cpu"] == 0
    assert nic["hpu_exec"] > 0 and host["hpu_exec"] == 0
    # ...and that removal explains the majority of the latency edge
    assert host["wall_ns"] > nic["wall_ns"]
    assert attr.explained_fraction(host, nic) >= 0.5
    table = attr.summarize(tr_host)
    assert "rpc-write" in table and "host_cpu" in table


def test_per_request_rows_cover_all_buckets():
    tr, rep = _traced("spin-triec", num_clients=2,
                      requests_per_client=2)
    rows = attr.per_request(tr)
    assert len(rows) == rep["completed"] == 4
    for row in rows.values():
        assert set(BUCKETS) <= set(row)
        assert row["wall_ns"] > 0
        assert row["wire"] > 0
