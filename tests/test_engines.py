"""Engine-equivalence suite: every core must tell the same story.

Three rings of agreement, from strictest out:

* **scalar lane** — with the EC flight lane off, the batched engine is
  bit-exact against the discrete reference: every report key except the
  ``events`` count (batching collapses the heap traffic by design).
* **flight lane** — with the analytic EC schedules on, count metrics
  (requests, bytes, packets, conservation) stay exact; time-derived
  metrics (goodput, latency) stay within a tolerance band — the lane
  books whole requests onto persistent frontiers in issue order, which
  shifts boundary packets but never invents or loses work.
* **hybrid** — calibration prefix + fluid extrapolation: counts still
  exact, times within a wider band.

Plus the (time, seq) determinism property: draining a tick as one batch
must fire callbacks in exactly the discrete engine's order.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - shim keeps the property tests on
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

import repro.policy as policy
from repro.sim.engine import (
    BatchedEngine,
    DiscreteEngine,
    ENGINES,
    HybridEngine,
    make_engine,
)
from repro.sim.protocols import Env
from repro.sim.pspin import PsPINConfig
from repro.sim.workload import Scenario, Workload

KiB = 1024

#: report keys that count work (must match exactly across engines)
COUNT_KEYS = (
    "issued", "completed", "dropped", "failed", "in_flight",
    "bytes_written", "bytes_read", "packets", "lost_packets",
    "lost_bytes", "ctrl_packets", "ctrl_bytes",
)
#: report keys derived from event times (tolerance-banded under flight)
TIME_KEYS = ("goodput_GBps", "mean_us", "p50_us")


def _run(sc: Scenario, engine, allow_flight: bool = True,
         pcfg: PsPINConfig | None = None) -> dict:
    w = Workload(sc, None, pcfg, engine=engine)
    if not allow_flight:
        w.env.allow_flight = False
    return w.run()


def _comparable(rep: dict) -> dict:
    """A report with engine-dependent event tallies stripped: the top-level
    ``events`` count and its echo inside the counter snapshot."""
    out = {k: v for k, v in rep.items() if k != "events"}
    out["counters"] = {k: v for k, v in rep["counters"].items()
                       if k != "sim.events"}
    return out


# -- engine selection ------------------------------------------------------


def test_make_engine_accepts_every_spec_form():
    assert isinstance(make_engine(), DiscreteEngine)
    assert isinstance(make_engine("discrete"), DiscreteEngine)
    assert isinstance(make_engine("batched"), BatchedEngine)
    assert isinstance(make_engine("hybrid"), HybridEngine)
    assert isinstance(make_engine(BatchedEngine), BatchedEngine)
    inst = HybridEngine()
    assert make_engine(inst) is inst
    with pytest.raises(ValueError):
        make_engine("warp-drive")


def test_engine_registry_names():
    assert set(ENGINES) == {"discrete", "batched", "hybrid"}
    assert not DiscreteEngine().batched
    assert BatchedEngine().batched
    assert HybridEngine().fluid


# -- scalar lane: bit-exact ------------------------------------------------


@pytest.mark.parametrize("protocol", ["spin-write", "chain-spin-write",
                                      "rdma-flat"])
def test_batched_scalar_lane_bit_exact(protocol):
    """No flight lane in play (replication presets): the batched engine
    must reproduce the discrete report exactly, events aside."""
    sc = Scenario(protocol=protocol, size=64 * KiB, num_clients=3,
                  requests_per_client=4, seed=11)
    ref = _comparable(_run(sc, "discrete"))
    got = _comparable(_run(sc, "batched"))
    for key in ref:
        assert got[key] == ref[key], (protocol, key, got[key], ref[key])


def test_batched_ec_scalar_lane_bit_exact_with_flight_off():
    """The EC pipeline through the batched engine's scalar path (flight
    explicitly disabled) is also bit-exact."""
    sc = Scenario(protocol="spin-triec", size=256 * KiB, num_clients=3,
                  requests_per_client=3, k=3, m=2, seed=7)
    ref = _comparable(_run(sc, "discrete"))
    got = _comparable(_run(sc, "batched", allow_flight=False))
    for key in ref:
        assert got[key] == ref[key], (key, got[key], ref[key])


# -- flight lane: counts exact, times banded -------------------------------


@pytest.fixture(scope="module")
def flight_reports():
    """One mid-size EC scenario on all three engines (the discrete
    reference dominates the cost; share it across the band tests).
    Flight-lane time deviation shrinks with scale — this size sits
    under 20%, the Fig. 16 anchor under 12%."""
    sc = Scenario(protocol="spin-triec", size=512 * KiB, num_clients=6,
                  requests_per_client=6, k=3, m=2, seed=7)
    pcfg = PsPINConfig(num_hpus=128)
    return {eng: _run(sc, eng, pcfg=pcfg)
            for eng in ("discrete", "batched", "hybrid")}


@pytest.mark.parametrize("engine", ["batched", "hybrid"])
def test_flight_lane_counts_exact_times_banded(flight_reports, engine):
    ref, got = flight_reports["discrete"], flight_reports[engine]
    assert got["events"] < ref["events"] / 10, "flight lane never engaged"
    for key in COUNT_KEYS:
        assert got[key] == ref[key], (key, got[key], ref[key])
    assert got["issued"] == got["completed"] + got["in_flight"] \
        + got["dropped"], "conservation violated"
    for key in TIME_KEYS:
        assert got[key] == pytest.approx(ref[key], rel=0.25), (
            key, got[key], ref[key])


def test_flight_lane_disabled_under_failures():
    """Failure injection must fall back to the real event pipeline (the
    lane's closed forms assume a healthy wire)."""
    fm = policy.FailureModel(crashed=(2,))
    sc = Scenario(protocol="spin-read-ec", size=128 * KiB, num_clients=2,
                  requests_per_client=3, k=3, m=2, seed=5, failures=fm)
    ref = _comparable(_run(sc, "discrete"))
    got = _comparable(_run(sc, "batched"))
    for key in ref:
        assert got[key] == ref[key], (key, got[key], ref[key])


# -- compile() facade ------------------------------------------------------


def test_compile_builds_env_with_engine():
    proto = policy.compile("spin-write", engine="batched")
    assert proto.env.sim.batched
    assert proto.request_bytes == policy.DEFAULT_REQUEST_BYTES


def test_compile_rejects_engine_with_existing_env():
    env = Env()
    with pytest.raises(ValueError):
        policy.compile("spin-write", env, engine="batched")
    with pytest.raises(ValueError):
        policy.compile("spin-write", env, cfg=object())


def _one_shot(proto):
    out = {}
    proto.issue(0, on_done=lambda res: out.setdefault("res", res))
    proto.env.sim.run()
    return out["res"]


def test_compile_policy_alias_matches_facade():
    spec = policy.preset_spec("spin-write")
    a = policy.compile(spec, Env(), 64 * KiB)
    b = policy.compile_policy(Env(), spec, 64 * KiB)
    assert _one_shot(a).latency_ns == _one_shot(b).latency_ns


# -- (time, seq) determinism property --------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40),
                min_size=1, max_size=50))
def test_batched_drain_preserves_time_seq_order(delays):
    """Batch-draining a tick fires callbacks in exactly the discrete
    engine's (time, seq) order — including ties and same-tick chains."""
    orders = []
    for cls in (DiscreteEngine, BatchedEngine):
        sim = cls()
        fired = []

        def chain(i, t):
            def fn():
                fired.append(i)
                # same-tick follow-up: must drain after every already-
                # queued event at this time, before any later time
                if i % 3 == 0:
                    sim.at(t, lambda: fired.append(-i - 1))
            return fn

        for i, d in enumerate(delays):
            sim.at(float(d), chain(i, float(d)))
        sim.run()
        assert sim.pending() == 0
        orders.append(fired)
    assert orders[0] == orders[1], delays
