"""Pool.resize edge cases (repro.sim.engine) — the HPU autoscaler actuator.

The control plane live-resizes HPU pools mid-run; these tests pin the
semantics the autoscaler relies on: every acquirer eventually runs
exactly once (request conservation, no deadlock) under shrink-below-
queued-waiters, grow-then-immediate-shrink, and resize-to-same-size.
"""

import pytest

from repro.sim.engine import Pool, Simulator


class _Load:
    """Issues ``n`` acquire/hold/release cycles and counts completions."""

    def __init__(self, sim: Simulator, pool: Pool, hold_ns: float = 10.0):
        self.sim = sim
        self.pool = pool
        self.hold_ns = hold_ns
        self.started = 0
        self.finished = 0

    def submit(self, n: int = 1) -> None:
        for _ in range(n):
            self.pool.acquire(self._run)

    def _run(self) -> None:
        self.started += 1
        self.sim.after(self.hold_ns, self._done)

    def _done(self) -> None:
        self.finished += 1
        self.pool.release()


def test_shrink_below_queued_waiters_conserves_requests():
    """Shrink to 1 while 8 are in flight and 12 queued: all 20 complete,
    and occupancy never exceeds capacity once the in-flight work drains."""
    sim = Simulator()
    pool = Pool(sim, 8)
    load = _Load(sim, pool)
    load.submit(20)          # 8 run, 12 queue
    assert pool.in_use == 8 and pool.queued() == 12
    pool.resize(1)
    sim.run()
    assert load.finished == 20
    assert pool.in_use == 0
    assert pool.queued() == 0


def test_shrink_retires_units_as_they_release():
    """After a shrink, releases retire surplus units instead of handing
    them to waiters beyond the new capacity."""
    sim = Simulator()
    pool = Pool(sim, 4)
    load = _Load(sim, pool, hold_ns=10.0)
    load.submit(4)
    pool.resize(2)
    load.submit(6)           # all queue: pool is over-occupied (4 > 2)
    occupancy = []

    def probe():
        occupancy.append(pool.in_use)
        if sim.pending() > 1:
            sim.after(5.0, probe)

    sim.after(15.0, probe)   # after the first batch released
    sim.run()
    assert load.finished == 10
    assert max(occupancy) <= 2


def test_grow_admits_queued_waiters_immediately():
    sim = Simulator()
    pool = Pool(sim, 1)
    load = _Load(sim, pool)
    load.submit(5)
    assert pool.queued() == 4
    pool.resize(4)
    assert pool.queued() == 1          # three admitted on the spot
    assert pool.in_use == 4
    sim.run()
    assert load.finished == 5


def test_grow_then_immediate_shrink():
    """grow(16) followed by shrink(2) in the same instant: the grow's
    admissions stand (they hold real units), the shrink only governs
    future hand-overs — no waiter is lost either way."""
    sim = Simulator()
    pool = Pool(sim, 2)
    load = _Load(sim, pool)
    load.submit(12)          # 2 run, 10 queue
    pool.resize(16)          # admits all 10
    assert pool.in_use == 12 and pool.queued() == 0
    pool.resize(2)           # immediately back down
    load.submit(6)           # these must wait for the drain
    sim.run()
    assert load.finished == 18
    assert pool.in_use == 0 and pool.queued() == 0


def test_resize_to_same_size_is_a_noop():
    sim = Simulator()
    pool = Pool(sim, 3)
    load = _Load(sim, pool)
    load.submit(7)
    before = (pool.in_use, pool.queued(), pool.peak)
    pool.resize(3)
    assert (pool.in_use, pool.queued(), pool.peak) == before
    sim.run()
    assert load.finished == 7


def test_repeated_thrash_never_deadlocks():
    """Alternating grow/shrink while load streams in: conservation holds
    and the run terminates (no lost hand-over, no stuck waiter)."""
    sim = Simulator()
    pool = Pool(sim, 4)
    load = _Load(sim, pool, hold_ns=7.0)
    sizes = [1, 9, 2, 16, 1, 3]

    def thrash(i=0):
        if i < len(sizes):
            pool.resize(sizes[i])
            load.submit(5)
            sim.after(11.0, lambda: thrash(i + 1))

    thrash()
    sim.run()
    assert load.started == load.finished == 30
    assert pool.in_use == 0 and pool.queued() == 0


def test_resize_rejects_nonpositive_capacity():
    pool = Pool(Simulator(), 2)
    with pytest.raises(ValueError):
        pool.resize(0)
    with pytest.raises(ValueError):
        pool.resize(-3)


def test_wait_accounting_survives_resize():
    """total_wait_ns counts only time actually spent queued, including
    waiters admitted by a grow."""
    sim = Simulator()
    pool = Pool(sim, 1)
    load = _Load(sim, pool, hold_ns=10.0)
    load.submit(2)           # second waits 10ns
    sim.after(4.0, lambda: pool.resize(2))  # admitted at t=4 -> 4ns wait
    sim.run()
    assert load.finished == 2
    assert pool.total_wait_ns == pytest.approx(4.0)
