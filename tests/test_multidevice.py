"""Multi-device collective tests (pipelined ring/PBT broadcast, resharding).

These need >1 device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (built by the
``multidevice_env`` conftest fixture, which skips when the forced device
count can't be satisfied) — the main test process keeps seeing 1 CPU
device, per the dry-run isolation rule.
"""

import subprocess
import sys

import pytest

_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from functools import partial
from repro.core.replication import ring_broadcast, pbt_broadcast, replicate
from repro.core.packets import ReplStrategy
from repro.parallel.compat import shard_map

mesh = jax.make_mesh((8,), ("r",))
rng = np.random.default_rng(0)
data = rng.standard_normal((8, 4, 32)).astype(np.float32)
x = jax.device_put(jnp.asarray(data), NamedSharding(mesh, P("r")))

for fn in (ring_broadcast, pbt_broadcast):
    for nc in (1, 4, 16):
        body = partial(fn, axis_name="r", num_chunks=nc, axis_size=8)
        out = np.asarray(jax.jit(shard_map(
            body, mesh=mesh, in_specs=P("r"), out_specs=P("r")))(x))
        for i in range(8):
            assert np.array_equal(out[i], data[0]), (fn.__name__, nc, i)

out = np.asarray(replicate(x, mesh, "r", ReplStrategy.PBT, num_chunks=4))
assert all(np.array_equal(out[i], data[0]) for i in range(8))

# elastic reshard: move a sharded tree onto a smaller mesh
from repro.runtime.elastic import build_mesh, reshard_state
tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh, P("r", None)))}
small = build_mesh(list(jax.devices())[:4], model_parallel=2)
assert dict(small.shape) == {"data": 2, "model": 2}
tree2 = reshard_state(tree, small)
assert np.array_equal(np.asarray(tree2["w"]), np.arange(64.0).reshape(8, 8))

# data pipeline with sharded device_put
from repro.data.pipeline import DataPipeline, PipelineConfig, SyntheticSource
sh = {"tokens": NamedSharding(mesh, P("r", None)),
      "labels": NamedSharding(mesh, P("r", None))}
pipe = DataPipeline(SyntheticSource(100, seed=3),
                    PipelineConfig(batch=8, seq=16), shardings=sh)
b = next(iter(pipe))
assert b["tokens"].sharding.spec == P("r", None)
pipe.close()
print("MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_multidevice_collectives(multidevice_env):
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=multidevice_env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MULTIDEVICE_OK" in proc.stdout


_MOE_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import moe as moe_mod

mesh = jax.make_mesh((2, 4), ("data", "model"))
E, K, d, ff, B, S = 8, 2, 32, 64, 4, 16
p = moe_mod.moe_init(jax.random.PRNGKey(0), d, ff, E)
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
want = moe_mod.moe_apply(p, x, E, K, dense_fallback=True)

xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
ps = dict(p)
ps["w_gate"] = jax.device_put(p["w_gate"], NamedSharding(mesh, P("model", "data", None)))
ps["w_up"] = jax.device_put(p["w_up"], NamedSharding(mesh, P("model", "data", None)))
ps["w_down"] = jax.device_put(p["w_down"], NamedSharding(mesh, P("model", None, "data")))
ps["router"] = {"w": jax.device_put(p["router"]["w"], NamedSharding(mesh, P("data", None)))}
with mesh:
    got = jax.jit(lambda pp, xx: moe_mod.moe_ep_apply(
        pp, xx, E, K, 8.0, mesh, ("data",), "model"))(ps, xs)
np.testing.assert_allclose(np.asarray(got, np.float32),
                           np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)

def loss(pp):
    return (moe_mod.moe_ep_apply(pp, xs, E, K, 8.0, mesh, ("data",), "model") ** 2).sum()
with mesh:
    g = jax.jit(jax.grad(loss))(ps)
gn = jax.tree.reduce(lambda a, v: a + float(jnp.sum(jnp.abs(v))), g, 0.0)
assert np.isfinite(gn) and gn > 0
print("MOE_EP_OK")
"""


@pytest.mark.slow
def test_moe_ep_shardmap(multidevice_env):
    """Explicit expert-parallel all-to-all dataflow matches the dense
    reference (no-drop capacity) and differentiates, on a 2x4 mesh."""
    proc = subprocess.run(
        [sys.executable, "-c", _MOE_SCRIPT], env=multidevice_env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MOE_EP_OK" in proc.stdout


_RING_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.collectives import (ring_all_gather, ring_reduce_scatter,
                                        ring_all_reduce, make_ring_collective)
from repro.parallel.compat import shard_map
mesh = jax.make_mesh((8,), ("r",))
rng = np.random.default_rng(0)
x = rng.standard_normal((16, 4)).astype(np.float32)
xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("r")))
ag = make_ring_collective(ring_all_gather, mesh, "r")(xs)
assert np.allclose(np.asarray(ag), x)
xr = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P()))
rs = make_ring_collective(ring_reduce_scatter, mesh, "r")(xr)
assert np.allclose(np.asarray(rs), 8 * x)
ar = make_ring_collective(ring_all_reduce, mesh, "r")(xr)
assert np.allclose(np.asarray(ar), 8 * x)
vs = jax.device_put(jnp.asarray(rng.standard_normal((64, 3)).astype(np.float32)),
                    NamedSharding(mesh, P("r")))
out = jax.jit(shard_map(lambda v: ring_all_reduce(v, "r", 8), mesh=mesh,
                        in_specs=P("r"), out_specs=P("r"), check_vma=False))(vs)
blocks = np.asarray(vs).reshape(8, 8, 3)
want = blocks.sum(axis=0)
got = np.asarray(out).reshape(8, 8, 3)
assert all(np.allclose(got[i], want, atol=1e-5) for i in range(8))
print("RING_OK")
"""


@pytest.mark.slow
def test_ring_collectives(multidevice_env):
    """Paper-style pipelined ring all-gather/reduce-scatter/all-reduce."""
    proc = subprocess.run(
        [sys.executable, "-c", _RING_SCRIPT], env=multidevice_env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "RING_OK" in proc.stdout
