"""Property tests for the control-plane actuator (repro.control.governor).

Hypothesis-or-shim properties:

  * TokenBucket conservation — under any interleaving of try_take /
    reserve / refill, the tokens granted never exceed the initial burst
    plus rate x elapsed time (no interleaving mints tokens);
  * reserve is a FIFO shaper — back-to-back reservation waits are
    monotone in debt, and the implied injection times respect the
    configured rate;
  * RepairPacer determinism — the same byte sequence under the same
    injected clock produces the same waits, and total sleep equals the
    bucket's ledger.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

import pytest

from repro.control.governor import RepairPacer, TokenBucket


def _steps(rnd, n=40):
    """A seeded op sequence: (dt, op, amount) triples."""
    out = []
    for _ in range(n):
        dt = rnd.uniform(0.0, 3.0)
        op = rnd.choice(["try_take", "reserve", "available"])
        amount = rnd.uniform(0.1, 50.0)
        out.append((dt, op, amount))
    return out


@settings(max_examples=30)
@given(st.randoms(), st.integers(min_value=1, max_value=100),
       st.integers(min_value=1, max_value=200))
def test_token_conservation(rnd, rate, burst):
    """Granted tokens <= burst + rate * elapsed, for any op interleaving.

    ``reserve`` grants immediately but charges a wait; counting a
    reservation as granted at ``now + wait`` keeps the bound exact."""
    b = TokenBucket(rate, burst)
    now = 0.0
    granted = 0.0          # via try_take (granted at `now`)
    horizon = 0.0          # latest time any reservation is injectable
    reserved = 0.0         # via reserve (granted at `now + wait`)
    for dt, op, amount in _steps(rnd):
        now += dt
        if op == "try_take":
            if b.try_take(amount, now):
                granted += amount
        elif op == "reserve":
            wait = b.reserve(amount, now)
            reserved += amount
            horizon = max(horizon, now + wait)
        else:
            assert 0.0 <= b.available(now) <= burst
        # everything handed out so far is covered by the refill up to
        # the latest injection time (reservations inject at now + wait)
        assert granted + reserved <= burst + rate * max(now, horizon) + 1e-6


@settings(max_examples=30)
@given(st.randoms(), st.integers(min_value=1, max_value=50))
def test_reserve_fifo_waits_monotone(rnd, rate):
    """Back-to-back reserves at one instant queue FIFO: each successive
    wait is >= the previous one, and equals the accumulated debt over
    the rate."""
    b = TokenBucket(rate, burst=rate)  # one time-unit of burst
    now = 1.0
    amounts = [rnd.uniform(0.1, 5.0 * rate) for _ in range(12)]
    waits = [b.reserve(a, now) for a in amounts]
    assert all(w2 >= w1 - 1e-12 for w1, w2 in zip(waits, waits[1:]))
    debt = sum(amounts) - rate  # burst absorbed one rate-unit
    assert waits[-1] == pytest.approx(max(0.0, debt / rate))
    assert b.total_wait == pytest.approx(sum(waits))


@settings(max_examples=20)
@given(st.randoms())
def test_reserve_then_wait_restores_rate(rnd):
    """After sleeping out the returned wait, the bucket owes nothing:
    an immediate availability check is non-negative and a tiny reserve
    waits ~0."""
    rate = rnd.uniform(1.0, 100.0)
    b = TokenBucket(rate, burst=rate)
    now = 0.0
    for _ in range(8):
        now += rnd.uniform(0.0, 1.0)
        wait = b.reserve(rnd.uniform(0.1, 3.0 * rate), now)
        now += wait  # the caller actually sleeps out the debt
    assert b.available(now) >= -1e-9
    assert b.reserve(1e-9, now) == pytest.approx(0.0, abs=1e-6)


def test_bucket_rejects_bad_config():
    with pytest.raises(ValueError):
        TokenBucket(0, 1)
    with pytest.raises(ValueError):
        TokenBucket(1, 0)


def test_try_take_sheds_and_ledgers():
    b = TokenBucket(rate=1.0, burst=10.0)
    assert b.try_take(10.0, now=0.0)      # drain the burst
    assert not b.try_take(5.0, now=0.0)   # empty: shed
    assert b.try_take(5.0, now=5.0)       # refilled 5 tokens
    assert (b.taken, b.shed) == (2, 1)


@settings(max_examples=15)
@given(st.randoms(), st.integers(min_value=1, max_value=64))
def test_repair_pacer_seeded_determinism(rnd, nshards):
    """Same shard sizes + same injected clock => identical waits; the
    pacer's ledger equals the sum of served waits."""
    sizes = [rnd.randint(1, 4 << 20) for _ in range(nshards)]

    def run():
        t = {"now": 100.0}
        slept = []

        def clock():
            return t["now"]

        def sleep(s):
            slept.append(s)
            t["now"] += s

        p = RepairPacer(rate_MBps=64.0, clock=clock, sleep=sleep)
        waits = [p.throttle(n) for n in sizes]
        return waits, slept, p

    w1, s1, p1 = run()
    w2, s2, p2 = run()
    assert w1 == w2 and s1 == s2
    assert p1.paced_bytes == sum(sizes)
    assert p1.paced_wait_s == pytest.approx(sum(s1))
    # pacing holds the configured rate: total injection time covers the
    # bytes beyond the burst
    total = sum(sizes)
    if total > 64e6:  # beyond the one-second burst
        assert sum(s1) >= (total - 64e6) / 64e6 - 1e-6
