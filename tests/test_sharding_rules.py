"""Sharding-rule unit tests: divisibility fallbacks, cache/batch specs.

Uses a tiny (2, 2) mesh built in a subprocess-free way: these tests only
inspect PartitionSpecs (no arrays are placed), so a 1-device mesh would
hide divisibility behavior — we construct a fake Mesh over the single CPU
device reshaped logically via jax.sharding.AbstractMesh.
"""

from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh
from repro.parallel.compat import abstract_mesh

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class _Leaf:
    def __init__(self, shape):
        self.shape = shape


def test_mesh_axes_detection():
    assert sh.MeshAxes.for_mesh(MESH).data == ("data",)
    assert sh.MeshAxes.for_mesh(MESH3).data == ("pod", "data")


def test_param_rules_shard_when_divisible():
    params = {
        "embed": {"table": _Leaf((64000, 4096))},
        "layers": {
            "attn": {"wq": {"w": _Leaf((48, 4096, 4096))}},
            "mlp": {"down": {"w": _Leaf((48, 11008, 4096))}},
        },
        "unembed": {"w": _Leaf((4096, 64000))},
        "ln": {"scale": _Leaf((4096,))},
    }
    specs = sh.param_specs(params, MESH)
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["down"]["w"] == P(None, "model", "data")
    assert specs["unembed"]["w"] == P("data", "model")
    assert specs["ln"]["scale"] == P(None)


def test_param_rules_fall_back_when_indivisible():
    # 20 heads x 128 = 2560 divides 16, but a 20-sized axis would not;
    # rules operate on flattened projection dims so this shards cleanly,
    # while a truly indivisible dim falls back.
    specs = sh.param_specs({"w_odd": {"w": _Leaf((17, 33))}}, MESH)
    assert specs["w_odd"]["w"] == P(None)


def test_moe_expert_specs():
    params = {
        "w_gate": _Leaf((16, 6144, 10752)),
        "w_down": _Leaf((16, 10752, 6144)),
    }
    specs = sh.param_specs(params, MESH)
    assert specs["w_gate"] == P("model", "data", None)
    assert specs["w_down"] == P("model", None, "data")


def test_batch_and_residual_specs():
    specs = sh.data_batch_specs({"tokens": (256, 4096)}, MESH)
    assert specs["tokens"] == P(("data",), None)
    # batch=1 (long_500k): not divisible -> unsharded
    specs1 = sh.data_batch_specs({"tokens": (1, 524288)}, MESH)
    assert specs1["tokens"] == P(None, None)
    assert sh.residual_spec(256, 4096, MESH) == P(("data",), "model", None)
    assert sh.residual_spec(1, 524288, MESH) == P(None, "model", None)


def test_cache_specs_never_shard_seq_and_find_batch():
    cache = {"k": _Leaf((32, 128, 32768, 8, 128))}   # (L, B, S, kv, hd)
    specs = sh.cache_specs(cache, MESH, max_len=32768, batch=128)
    spec = specs["k"]
    assert spec[2] is None                       # seq never sharded
    assert spec[1] in ("data", ("data",))        # batch found by value, not L
    assert spec[0] is None                       # layer axis NOT data-sharded
    assert spec[4] == "model"                    # hd divisible

    # MLA latent cache (L, B, S, lora)
    mla = {"c": _Leaf((26, 128, 32768, 512))}
    spec = sh.cache_specs(mla, MESH, max_len=32768, batch=128)["c"]
    assert spec[3] == "model" and spec[1] in ("data", ("data",))
    assert spec[2] is None


def test_moe_buffer_spec():
    assert sh.moe_buffer_spec(16, MESH, 256) == P(("data",), "model", None, None)
    assert sh.moe_buffer_spec(10, MESH, 256) is None   # E % 16 != 0
