"""Control-plane invariants: telemetry ring, governor, autoscaler, sweep.

(a) token bucket — refill/admission/pacing arithmetic and determinism
(b) telemetry — window attribution conserves the metrics ledger exactly;
    the event-time sampler terminates and gauges are recorded
(c) workload integration — admission sheds are counted (conservation
    holds), pacing delays injection without losing requests, background
    loads land in repair_bytes
(d) autoscaler — SLO scoring, bisection convergence to the minimal HPU
    count (within one doubling of a brute-force scan), determinism
(e) engine — live Pool resize admits/retires correctly
(f) sweep — quick artifact has the gated claim schema
"""

import dataclasses
import math

import pytest

from repro.control import (
    SLO,
    Autoscaler,
    RepairPacer,
    Telemetry,
    TokenBucket,
)
from repro.control.sweep import bench_rows, pacing_scenario, write_artifact
from repro.sim.engine import Pool, Simulator
from repro.sim.workload import (
    KiB,
    PolicyLoad,
    Scenario,
    SizeDist,
    Workload,
    run_scenario,
)


def _conserves(rep: dict) -> bool:
    return rep["issued"] == rep["completed"] + rep["in_flight"] + rep["dropped"]


# -- (a) token bucket --------------------------------------------------------


def test_bucket_refills_at_rate():
    b = TokenBucket(rate=2.0, burst=10.0)
    assert b.try_take(10.0, now=0.0)          # drain the burst
    assert not b.try_take(1.0, now=0.0)       # empty: shed
    assert b.shed == 1
    assert b.try_take(4.0, now=2.0)           # 2 time units * rate 2 == 4
    assert not b.try_take(1.0, now=2.0)


def test_bucket_reserve_paces_fifo():
    b = TokenBucket(rate=1.0, burst=5.0)
    assert b.reserve(5.0, now=0.0) == 0.0     # burst covers it
    w1 = b.reserve(3.0, now=0.0)              # 3 tokens of debt
    w2 = b.reserve(2.0, now=0.0)              # queues behind w1
    assert w1 == pytest.approx(3.0)
    assert w2 == pytest.approx(5.0)
    assert b.total_wait == pytest.approx(8.0)


def test_bucket_never_exceeds_burst():
    b = TokenBucket(rate=100.0, burst=8.0)
    b.try_take(8.0, now=0.0)
    assert b.available(1e9) == pytest.approx(8.0)


def test_bucket_validates():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0.0)


def test_repair_pacer_sleeps_out_debt():
    t = {"now": 0.0}
    slept = []

    def sleep(s):
        slept.append(s)
        t["now"] += s

    pacer = RepairPacer(rate_MBps=1.0, burst_bytes=1e6,
                        clock=lambda: t["now"], sleep=sleep)
    assert pacer.throttle(1_000_000) == 0.0   # burst covers the first MB
    wait = pacer.throttle(2_000_000)          # 2 s of debt at 1 MB/s
    assert wait == pytest.approx(2.0)
    assert slept == [pytest.approx(2.0)]
    assert pacer.paced_bytes == 3_000_000


# -- (b) telemetry ring ------------------------------------------------------


def test_windows_conserve_ledger():
    sc = Scenario(protocol="spin-write", size=64 * KiB, num_clients=4,
                  requests_per_client=6, seed=2)
    tel = Telemetry(window_ns=20_000.0)
    w = Workload(sc, telemetry=tel)
    rep = w.run()
    assert sum(win.completed for win in tel.windows) == rep["completed"]
    assert sum(win.issued for win in tel.windows) == rep["issued"]
    assert sum(len(win.latencies_ns) for win in tel.windows) == rep["completed"]
    assert sum(win.bytes for win in tel.windows) == w.metrics.bytes_completed
    # the sampler ran and saw the HPU pool in use at least once
    assert any(win.samples > 0 for win in tel.windows)
    assert max(win.hpu_in_use_max for win in tel.windows) >= 1
    # windows are strictly ordered
    idxs = [win.index for win in tel.windows]
    assert idxs == sorted(idxs) and len(set(idxs)) == len(idxs)


def test_ring_is_bounded():
    tel = Telemetry(window_ns=10.0, capacity=4)
    for i in range(10):
        tel.record_issue(i * 10.0)
    assert len(tel.windows) == 4
    assert tel.evicted == 6
    assert [w.index for w in tel.windows] == [6, 7, 8, 9]


def test_summary_skips_warmup():
    tel = Telemetry(window_ns=10.0)
    for i in range(10):
        tel.record_complete(i * 10.0 + 5.0, latency_ns=100.0 * (10 - i),
                            nbytes=1000)
    full = tel.summary(warmup_frac=0.0)
    steady = tel.summary(warmup_frac=0.5)
    assert full["completed"] == 10
    assert steady["completed"] == 5
    # warmup windows held the slowest completions
    assert steady["p99_ns"] < full["p99_ns"]


def test_background_latencies_stay_out_of_p99():
    """A paced rebuild's long transfers must not masquerade as
    foreground tail latency in the SLO signal."""
    tel = Telemetry(window_ns=10.0)
    for i in range(8):
        tel.record_complete(i * 10.0 + 1.0, latency_ns=100.0, nbytes=10)
        tel.record_complete(i * 10.0 + 2.0, latency_ns=1e6, nbytes=1000,
                            background=True)
    summ = tel.summary(warmup_frac=0.0)
    assert summ["completed"] == 16
    assert summ["p99_ns"] == pytest.approx(100.0)
    assert sum(w.bg_completed for w in tel.windows) == 8
    assert summ["repair_GBps"] > 0
    # goodput counts foreground bytes only
    assert summ["goodput_GBps"] == pytest.approx(80 / 80.0)


def test_summary_widens_when_warmup_eats_all_completions():
    tel = Telemetry(window_ns=10.0)
    tel.record_complete(5.0, latency_ns=123.0, nbytes=10)
    for i in range(1, 10):
        tel.record_issue(i * 10.0 + 5.0)  # later windows: no completions
    summ = tel.summary(warmup_frac=0.5)
    assert summ["p99_ns"] == pytest.approx(123.0)


def test_telemetry_validates():
    with pytest.raises(ValueError):
        Telemetry(window_ns=0.0)


# -- (c) workload integration -----------------------------------------------


def test_admission_sheds_and_conserves():
    base = Scenario(protocol="spin-write", size=256 * KiB, num_clients=4,
                    arrival="poisson", offered_load_GBps=40.0,
                    requests_per_client=10, seed=4)
    free = run_scenario(base)
    throttled = run_scenario(
        dataclasses.replace(base, admission_GBps=2.0,
                            admission_burst_bytes=256 * KiB)
    )
    assert _conserves(free) and _conserves(throttled)
    assert throttled["admission_shed"] > 0
    assert throttled["dropped"] >= throttled["admission_shed"]
    assert throttled["completed"] < free["completed"]


def test_closed_loop_admission_backpressures_not_drains():
    """Closed-loop clients are elastic: an empty admission bucket delays
    the next request until refill instead of shedding — nothing is
    dropped and the aggregate rate is pinned to the configured budget
    (an earlier bug drained the whole remaining budget at one instant)."""
    base = Scenario(protocol="spin-write", size=256 * KiB, num_clients=4,
                    requests_per_client=8, seed=4)
    free = run_scenario(base)
    held = run_scenario(
        dataclasses.replace(base, admission_GBps=5.0,
                            admission_burst_bytes=1 << 20)
    )
    assert _conserves(free) and _conserves(held)
    assert held["dropped"] == 0
    assert held["completed"] == free["completed"]
    # the run is stretched to the admitted rate (well below the ~48 GB/s
    # unthrottled goodput, with slack for the initial burst)
    assert held["goodput_GBps"] < 8.0
    assert held["sim_ns"] > free["sim_ns"]


def test_telemetry_counts_loss_including_final_window():
    """Every lost packet the network counted reaches the ring — the
    final flush covers drops after the last periodic tick and runs
    shorter than one window."""
    from repro.policy import FailureModel

    sc = Scenario(protocol="spin-write", size=64 * KiB, num_clients=4,
                  requests_per_client=6, seed=2,
                  failures=FailureModel(loss=((1, 0.3),), seed=7))
    tel = Telemetry(window_ns=1e9)  # one window: only the flush samples
    w = Workload(sc, telemetry=tel)
    rep = w.run()
    assert rep["lost_packets"] > 0
    assert sum(win.lost_packets for win in tel.windows) == rep["lost_packets"]
    assert sum(win.lost_bytes for win in tel.windows) == rep["lost_bytes"]


def test_admission_rejects_undersized_burst():
    # a 2 MiB request can never pass a 1 MiB-deep bucket: constructing
    # the workload must fail loudly instead of shedding 100% silently
    sc = Scenario(protocol="spin-write", size=2 << 20,
                  admission_GBps=40.0, admission_burst_bytes=1 << 20)
    with pytest.raises(ValueError, match="admission_burst_bytes"):
        Workload(sc)
    dist = Scenario(protocol="spin-write", size=64 * KiB,
                    size_dist=SizeDist("lognormal", mean=64 * KiB,
                                       max_bytes=4 << 20),
                    admission_GBps=40.0, admission_burst_bytes=1 << 20)
    with pytest.raises(ValueError, match="admission_burst_bytes"):
        Workload(dist)


def test_pacing_delays_without_loss():
    unpaced = run_scenario(pacing_scenario(None, quick=True))
    paced = run_scenario(pacing_scenario(4.0, quick=True))
    assert _conserves(unpaced) and _conserves(paced)
    # pacing delays injection; it never sheds
    assert paced["completed"] == unpaced["completed"]
    assert paced["paced_wait_us"] > 0.0
    assert unpaced["paced_wait_us"] == 0.0
    fg_paced = paced["per_policy"]["spin-write"]["p99_us"]
    fg_unpaced = unpaced["per_policy"]["spin-write"]["p99_us"]
    assert fg_paced < fg_unpaced


def test_background_bytes_land_in_repair():
    sc = Scenario(
        policies=[
            PolicyLoad("spin-write", 1.0, SizeDist("fixed", mean=64 * KiB)),
            PolicyLoad("spin-triec", 1.0, SizeDist("fixed", mean=256 * KiB),
                       background=True),
        ],
        size=64 * KiB, num_clients=2, requests_per_client=4,
        k=3, m=2, seed=6,
    )
    tel = Telemetry(window_ns=20_000.0)
    w = Workload(sc, telemetry=tel)
    rep = w.run()
    repair = sum(win.repair_bytes for win in tel.windows)
    fg = sum(win.bytes for win in tel.windows)
    assert repair == rep["per_policy"]["spin-triec"]["bytes"]
    assert fg == rep["per_policy"]["spin-write"]["bytes"]
    assert repair > 0 and fg > 0


def test_paced_workload_deterministic():
    sc = pacing_scenario(4.0, quick=True)
    assert run_scenario(sc) == run_scenario(sc)


# -- (d) SLO + autoscaler ----------------------------------------------------


def test_slo_scoring():
    slo = SLO(p99_ns=100.0, goodput_frac=0.5)
    assert slo.attainment(50.0, 25.0, 50.0) == pytest.approx(1.0)
    assert slo.attainment(200.0, 50.0, 50.0) == pytest.approx(0.5)
    assert slo.binding(200.0, 50.0, 50.0) == "p99"
    assert slo.binding(10.0, 5.0, 50.0) == "goodput"
    assert SLO().attainment(1e9, 0.0, 50.0) == math.inf
    assert slo.attainment(math.nan, 25.0, 50.0) == 0.0


def test_autoscaler_validates():
    with pytest.raises(ValueError):
        Autoscaler(SLO(p99_ns=1.0), hpu_min=0)
    with pytest.raises(ValueError):
        Autoscaler(SLO(p99_ns=1.0), hpu_min=8, hpu_max=4)


TRIEC_SC = Scenario(protocol="spin-triec", size=256 * KiB, num_clients=4,
                    requests_per_client=4, k=3, m=2, seed=3)
TRIEC_SLO = SLO(p99_ns=150_000.0)


def test_autoscaler_converges_to_minimum():
    scaler = Autoscaler(TRIEC_SLO, hpu_max=256)
    res = scaler.run(TRIEC_SC, start_hpus=8)
    assert res.met
    # the converged count meets the SLO...
    assert scaler.run_epoch(TRIEC_SC, res.num_hpus).met
    # ...and one HPU fewer violates it (true minimality, not an upper
    # bound) unless we bottomed out
    if res.num_hpus > scaler.hpu_min:
        assert not scaler.run_epoch(TRIEC_SC, res.num_hpus - 1).met


def test_autoscaler_within_doubling_of_static_scan():
    scaler = Autoscaler(TRIEC_SLO, hpu_max=256)
    static = next(
        h for h in (1, 2, 4, 8, 16, 32, 64, 128, 256)
        if scaler.run_epoch(TRIEC_SC, h).met
    )
    res = scaler.run(TRIEC_SC, start_hpus=32)
    assert res.met and res.num_hpus <= 2 * static


def test_autoscaler_reports_unattainable():
    scaler = Autoscaler(SLO(p99_ns=1.0), hpu_max=4, max_epochs=6)
    res = scaler.run(TRIEC_SC, start_hpus=1)
    assert not res.met
    assert res.num_hpus == 4


def test_autoscaler_deterministic():
    scaler = Autoscaler(TRIEC_SLO, hpu_max=256)
    a = scaler.run(TRIEC_SC, start_hpus=8)
    b = Autoscaler(TRIEC_SLO, hpu_max=256).run(TRIEC_SC, start_hpus=8)
    assert a.num_hpus == b.num_hpus
    assert [(e.num_hpus, e.attainment) for e in a.epochs] == [
        (e.num_hpus, e.attainment) for e in b.epochs
    ]


def test_pick_fanout_returns_cheapest():
    scaler = Autoscaler(SLO(p99_ns=300_000.0), hpu_max=256)
    best, res, all_h = scaler.pick_fanout(TRIEC_SC, [(3, 2), (6, 3)])
    assert best in all_h and res.met
    assert res.num_hpus == min(all_h.values())


def test_fanout_resizes_policy_spec_loads():
    from repro.policy import PolicySpec, RS, SpongeAuth

    spec = PolicySpec("spin", SpongeAuth(), erasure=RS(4, 2, "spin"))
    sc = Scenario(
        policies=[
            PolicyLoad(spec, 1.0),
            PolicyLoad("spin-write", 1.0),  # no fan-out: must pass through
        ],
        size=64 * KiB, num_clients=2, requests_per_client=2, seed=1,
    )
    out = Autoscaler._scenario_with_geometry(sc, 6, 3)
    assert out.k == 6 and out.m == 3
    assert out.policies[0].spec.erasure.k == 6
    assert out.policies[0].spec.erasure.m == 3
    assert out.policies[1].spec == "spin-write"
    # the resized scenario actually compiles and runs
    rep = run_scenario(out)
    assert _conserves(rep) and rep["completed"] > 0


def test_with_geometry_semantics():
    from repro.policy import Flat, NoAuth, PolicySpec, RS, SpongeAuth, Tree

    ec = PolicySpec("spin", SpongeAuth(), erasure=RS(4, 2, "spin"))
    assert ec.with_geometry(6, 3).erasure == RS(6, 3, "spin")
    assert ec.with_geometry(10).erasure == RS(10, 2, "spin")  # m kept
    repl = PolicySpec("spin", SpongeAuth(), replication=Tree(2))
    assert repl.with_geometry(4).replication.k == 4
    with pytest.raises(ValueError):
        repl.with_geometry(4, 2)  # replication has no parity count
    with pytest.raises(ValueError):
        PolicySpec("rdma", NoAuth()).with_geometry(2)  # nothing to resize
    assert PolicySpec("rdma", NoAuth(), Flat(2)).with_geometry(3).replication.k == 3


# -- (e) live pool resize ----------------------------------------------------


def test_pool_resize_grow_admits_waiters():
    sim = Simulator()
    pool = Pool(sim, capacity=1)
    ran = []
    pool.acquire(lambda: ran.append("a"))
    pool.acquire(lambda: ran.append("b"))   # queued
    assert ran == ["a"] and pool.queued() == 1
    pool.resize(2)
    sim.run()
    assert ran == ["a", "b"]
    assert pool.in_use == 2 and pool.peak == 2


def test_pspin_unit_live_resize():
    from repro.sim.protocols import Env

    env = Env()
    unit = env.pspin(1)
    ran = []
    for _ in range(unit.hpus.capacity):
        unit.hpus.acquire(lambda: ran.append("x"))
    unit.hpus.acquire(lambda: ran.append("queued"))
    assert unit.hpus.queued() == 1
    unit.resize(unit.hpus.capacity + 1)
    env.sim.run()
    assert ran[-1] == "queued" and unit.hpus.queued() == 0


def test_pool_resize_shrink_retires_on_release():
    sim = Simulator()
    pool = Pool(sim, capacity=2)
    pool.acquire(lambda: None)
    pool.acquire(lambda: None)
    pool.resize(1)
    pool.release()
    assert pool.in_use == 1                 # retired, not handed over
    ran = []
    pool.acquire(lambda: ran.append("c"))   # queued at the new capacity
    assert pool.queued() == 1
    pool.release()
    sim.run()
    assert ran == ["c"]
    with pytest.raises(ValueError):
        pool.resize(0)


# -- (f) sweep artifact schema ----------------------------------------------


@pytest.mark.slow
def test_quick_sweep_claims_schema(tmp_path):
    rows, claims = bench_rows(quick=True)
    assert rows
    for key in (
        "fig16_goodput_frac", "fig16_saturation_gain",
        "fig16_knee_within_doubling", "autoscale_within_doubling",
        "pacing_slo_p99_us", "paced_fg_p99_us", "unpaced_fg_p99_us",
        "pacing_holds_slo",
    ):
        assert key in claims, key
    assert claims["autoscale_within_doubling"] >= 3
    assert claims["pacing_holds_slo"]
    out = tmp_path / "control.json"
    write_artifact(rows, claims, str(out), {"quick": True})
    import json

    doc = json.loads(out.read_text())
    assert doc["bench"] == "control" and doc["claims"] and doc["rows"]
