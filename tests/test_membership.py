"""Failure detection, leases, and view-change reconfiguration.

(a) detector — EWMA timeout adaptation, the monotone
    alive -> suspect -> dead ladder, revoked suspicion as the measured
    false-positive channel, and the no-resurrection rule;
(b) view manager — lease wait-out before activation, monotone view
    numbers, no rejoin;
(c) retry policy — exponential growth, cap, bounded jitter, exhaustion;
(d) timed plane — heartbeats as costed NIC traffic in the ctrl byte
    counters, detection-driven chain failover under scheduled crashes /
    partitions, gray-failure (flap) tolerance, and the static
    (anchor-exact) compile staying the default without a service;
(e) functional plane — the harness where ``crash()`` only silences a
    node: detection latency, lease-gated activation, epoch fencing,
    cross-view linearizability over the crash x partition x flap grid
    (tier-1 subset here, full grid in the slow lane), and ABD losing
    availability but never safety when the quorum goes unreachable;
(f) workload accounting — heartbeat bytes ride the ctrl_* counters,
    never data goodput; failed requests balance the conservation ledger.
"""

import random

import pytest

from repro.core.handlers import ReplicationHarness
from repro.membership import (
    DEAD,
    MONITOR,
    SUSPECT,
    FailureDetector,
    MembershipConfig,
    RetryExhausted,
    RetryPolicy,
    ViewManager,
    attach_membership,
)
from repro.policy import FailureModel, preset_spec
from repro.policy.timed import compile_policy
from repro.sim import protocols as P
from repro.verify.linearize import check_records

pytestmark = pytest.mark.membership

KiB = 1024


# -- (a) failure detector ----------------------------------------------------


def test_config_validation():
    with pytest.raises(ValueError, match="interval"):
        MembershipConfig(interval=0)
    with pytest.raises(ValueError, match="suspect_after"):
        MembershipConfig(suspect_after=5.0, dead_after=3.0)
    with pytest.raises(ValueError, match="lease"):
        MembershipConfig(lease=-1.0)
    cfg = MembershipConfig(interval=10.0, dead_after=6.0)
    assert cfg.dead_timeout == 60.0
    assert cfg.lease_span == 60.0          # lease defaults to dead timeout
    assert MembershipConfig(lease=25.0).lease_span == 25.0


def test_detector_ladder_is_monotone():
    cfg = MembershipConfig(interval=10.0, suspect_after=3.0, dead_after=6.0)
    d = FailureDetector([1], cfg)
    for t in (10.0, 20.0, 30.0):
        d.record(1, t)
    assert d.poll(40.0) == []                       # silence 10 < 30
    assert d.poll(61.0) == [(1, SUSPECT)]           # silence 31 >= 30
    assert d.poll(70.0) == []                       # still suspect, once
    assert d.poll(95.0) == [(1, DEAD)]              # silence 65 >= 60
    assert d.poll(200.0) == []                      # dead is terminal


def test_detector_jumps_straight_to_dead_after_long_silence():
    d = FailureDetector([1], MembershipConfig(interval=10.0))
    # one poll far past both thresholds yields both transitions
    assert d.poll(1000.0) == [(1, SUSPECT), (1, DEAD)]


def test_false_suspicion_is_revoked_and_counted():
    cfg = MembershipConfig(interval=10.0, suspect_after=2.0, dead_after=6.0)
    d = FailureDetector([1, 2], cfg)
    assert d.poll(25.0) == [(1, SUSPECT), (2, SUSPECT)]
    d.record(1, 26.0)                               # node 1 was just slow
    assert d.state[1] != SUSPECT and d.state[2] == SUSPECT
    assert d.false_suspects == 1
    assert (26.0, 1, "alive") in d.transitions


def test_dead_node_heartbeats_do_not_resurrect():
    d = FailureDetector([1], MembershipConfig(interval=10.0))
    d.poll(1000.0)
    assert d.state[1] == DEAD
    d.record(1, 1001.0)
    assert d.state[1] == DEAD and d.late_heartbeats == 1


def test_ewma_stretches_a_jittery_nodes_timeout():
    """A node that heartbeats reliably every 3 intervals adapts its
    effective timeout upward instead of flapping suspect/alive."""
    cfg = MembershipConfig(interval=10.0, suspect_after=3.0, dead_after=6.0)
    d = FailureDetector([1], cfg)
    for t in range(30, 600, 30):                    # gap 30 = 3x interval
        d.record(1, float(t))
    assert d.effective_interval(1) > 25.0
    # silence of 5 nominal intervals is within 2x the adapted interval
    assert d.poll(d.last[1] + 50.0) == []
    fixed = FailureDetector([1], MembershipConfig(interval=10.0,
                                                  adaptive=False))
    assert fixed.effective_interval(1) == 10.0


# -- (b) view manager --------------------------------------------------------


def test_view_waits_out_the_removed_nodes_lease():
    cfg = MembershipConfig(interval=10.0, suspect_after=3.0, dead_after=6.0)
    vm = ViewManager([1, 2, 3], cfg)
    for t in (10.0, 20.0, 30.0):
        for n in (1, 2, 3):
            vm.record_heartbeat(n, t)
    # node 3 goes silent after t=30: lease runs to 30 + 60 = 90
    for t in (40.0, 50.0, 60.0, 70.0, 80.0, 90.0):
        vm.record_heartbeat(1, t)
        vm.record_heartbeat(2, t)
        vm.poll(t)
    assert 3 in vm.removed and vm.detected_at(3) is not None
    assert vm.pending_change() and vm.activation_at() == 90.0
    assert vm.poll(90.0) is None                    # not strictly past
    new = vm.poll(90.5)                             # lease expired: activate
    assert new is not None and new.number == 2 and new.members == (1, 2)
    assert 3 not in new


def test_removed_node_never_rejoins_and_gets_no_lease():
    cfg = MembershipConfig(interval=10.0, suspect_after=2.0, dead_after=4.0)
    vm = ViewManager([1, 2], cfg)
    vm.record_heartbeat(1, 50.0)
    vm.poll(50.0)                                   # node 2 silent -> dead
    assert 2 in vm.removed
    lease_before = vm.lease_until[2]
    vm.record_heartbeat(2, 55.0)                    # back from the dead
    assert vm.lease_until[2] == lease_before        # no renewal
    assert vm.detector.late_heartbeats == 1
    vm.record_heartbeat(1, 190.0)                   # node 1 stays alive
    vm.poll(200.0)
    assert vm.view.members == (1,)
    vm.record_heartbeat(2, 201.0)                   # still no way back
    vm.record_heartbeat(1, 210.0)
    vm.poll(220.0)
    assert 2 not in vm.view.members and vm.view.number == 2


def test_view_numbers_are_monotone_across_cascading_failures():
    cfg = MembershipConfig(interval=10.0, suspect_after=2.0, dead_after=4.0)
    vm = ViewManager([1, 2, 3], cfg)
    changes = []
    vm.on_change.append(changes.append)
    vm.record_heartbeat(1, 60.0)                    # 2 and 3 silent
    vm.record_heartbeat(1, 100.0)
    vm.poll(100.0)
    assert vm.view.number == 2 and vm.view.members == (1,)
    numbers = [v.number for _, v in vm.view_log]
    assert numbers == sorted(numbers) == list(range(1, len(numbers) + 1))
    assert [v.number for v in changes] == numbers[1:]


# -- (c) retry policy --------------------------------------------------------


def test_retry_policy_grows_caps_and_jitters():
    rp = RetryPolicy(base=100.0, mult=2.0, cap=400.0, jitter=0.2,
                     max_attempts=8)
    rng = random.Random(0)
    for attempt, nominal in ((0, 100.0), (1, 200.0), (2, 400.0), (5, 400.0)):
        for _ in range(20):
            d = rp.delay(attempt, rng)
            assert nominal * 0.8 <= d <= nominal * 1.2
    spread = {round(rp.delay(0, rng), 3) for _ in range(20)}
    assert len(spread) > 1                           # jitter actually varies
    assert RetryPolicy(base=10.0, jitter=0.0).delay(0, rng) == 10.0


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(base=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(base=1.0, max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(base=1.0, jitter=1.5)


# -- (d) timed plane ---------------------------------------------------------


def _timed_chain(failures, membership_cfg, nwrites=30, gap_ns=100_000.0,
                 horizon_ns=4_000_000.0, k=3):
    """Compile a membership-aware chain, stream writes, run to quiescence.

    Returns (service, protocol, results) where results is a list of
    (index, Result)."""
    env = P.Env(failures=failures)
    svc = attach_membership(env, tuple(range(1, k + 1)), membership_cfg)
    proto = compile_policy(env, preset_spec("chain-spin-write", k=k),
                           16 * KiB)
    done = []
    for i in range(nwrites):
        env.sim.at(i * gap_ns,
                   lambda i=i: proto.issue(
                       P.CLIENT, on_done=lambda r, i=i: done.append((i, r))))
    # sentinel: keeps the heartbeat tick alive through the horizon even
    # after the data plane drains (pure-detection tail)
    env.sim.at(horizon_ns, lambda: None)
    env.sim.run()
    return svc, proto, done


def test_timed_heartbeats_are_ctrl_traffic_with_handler_cost():
    env = P.Env()
    svc = attach_membership(env, (1, 2, 3),
                            MembershipConfig(interval=20_000.0))
    env.sim.at(500_000.0, lambda: None)
    env.sim.run()
    net = env.net
    assert svc.hb_emitted > 0 and svc.hb_received == svc.hb_emitted
    assert net.ctrl_packets_sent == svc.hb_emitted
    assert net.ctrl_bytes_sent == 44 * svc.hb_emitted
    # control traffic never leaks into the data counters
    assert net.packets_sent == 0 and net.packets_dropped == 0
    # the emitting NIC actually ran a handler (heartbeat is costed)
    assert env.pspin(1).hpus.peak >= 1


def test_timed_crash_is_detected_within_the_timeout_budget():
    cfg = MembershipConfig(interval=20_000.0)    # dead timeout 100 us
    crash_ns = 1_000_000.0
    svc, proto, done = _timed_chain(
        FailureModel(crash_at=((crash_ns, 1),)), cfg)
    det = svc.views.detected_at(1)
    assert det is not None
    # silence starts at the last pre-crash heartbeat (at most one
    # interval before the crash); the verdict lands on a poll, at most
    # one interval after crossing the threshold
    assert crash_ns < det <= crash_ns + cfg.dead_timeout + cfg.interval
    assert svc.views.view.number == 2
    assert svc.views.view.members == (2, 3)


def test_timed_failover_completes_every_write_via_detected_view():
    cfg = MembershipConfig(interval=20_000.0)
    svc, proto, done = _timed_chain(
        FailureModel(crash_at=((1_000_000.0, 1),)), cfg)
    assert len(done) == 30
    failed = [i for i, r in done if r.extra.get("failed")]
    assert failed == []                          # retries rode the change
    assert proto.retries >= 1                    # ...and were needed
    # unavailability window: writes issued inside the detection window
    # retried and still landed, bounded by the backoff budget
    worst = max(r.latency_ns for _, r in done)
    assert worst < 4.0 * (cfg.dead_timeout + 250_000.0)


def test_timed_partition_removes_node_and_fences_stale_epochs():
    cfg = MembershipConfig(interval=20_000.0)
    svc, proto, done = _timed_chain(
        FailureModel(partitions=((1_000_000.0, 3_000_000.0, (2,)),)),
        cfg, nwrites=40, horizon_ns=5_000_000.0)
    assert svc.views.detected_at(2) is not None
    assert svc.views.view.members == (1, 3)
    assert all(not r.extra.get("failed") for _, r in done)
    # packets issued under view 1 that landed after view 2 activated
    # were fenced (counted), and partitioned heartbeats were dropped as
    # control bytes, not data loss
    assert proto.fenced > 0
    assert proto.env.net.ctrl_packets_dropped > 0
    assert proto.env.net.packets_dropped == 0 or proto.retries > 0


def test_timed_flap_is_gray_not_dead():
    """A node unreachable 30% of the time keeps its heartbeats frequent
    enough that the detector never removes it; the data path retries
    through the flap instead of reconfiguring."""
    cfg = MembershipConfig(interval=20_000.0)
    svc, proto, done = _timed_chain(
        FailureModel(flap=((2, 50_000.0, 0.3),)), cfg,
        nwrites=40, horizon_ns=5_000_000.0)
    assert svc.views.removed == set()
    assert svc.views.view.number == 1
    assert all(not r.extra.get("failed") for _, r in done)
    assert proto.retries > 0                     # the flap was felt


def test_timed_lossy_monitor_causes_suspicion_not_removal():
    """Heavy loss toward the monitor + a straggler NIC: suspicion
    flickers (the measured FP channel) but dead verdicts need
    dead_after consecutive silent intervals, which loss alone does not
    produce at these settings."""
    env = P.Env(failures=FailureModel(loss=((MONITOR, 0.4),),
                                      slow=((2, 8.0),), seed=7))
    svc = attach_membership(env, (1, 2, 3),
                            MembershipConfig(interval=20_000.0,
                                             suspect_after=2.0,
                                             dead_after=8.0))
    env.sim.at(5_000_000.0, lambda: None)
    env.sim.run()
    assert svc.views.detector.false_suspects > 0
    assert svc.views.removed == set()
    assert svc.views.view.number == 1


def test_timed_static_compile_is_default_without_membership():
    """No service attached -> the legacy compile-time chain (the
    anchor-exact baseline) — detection only ever changes behavior when
    explicitly attached."""
    from repro.policy.timed import ChainSpinSink

    env = P.Env()
    proto = compile_policy(env, preset_spec("chain-spin-write", k=3),
                           16 * KiB)
    sinks = [s for s in proto.sinks.values()
             if isinstance(s, ChainSpinSink)]
    assert sinks and all(s.membership is None for s in sinks)
    assert any(s.succ is not None for s in sinks)   # static routing wired


def test_timed_retry_budget_exhausts_cleanly_when_all_replicas_die():
    cfg = MembershipConfig(interval=20_000.0)
    fm = FailureModel(crash_at=((100_000.0, 1), (100_000.0, 2),
                                (100_000.0, 3)))
    env = P.Env(failures=fm)
    svc = attach_membership(env, (1, 2, 3), cfg)
    proto = compile_policy(env, preset_spec("chain-spin-write", k=3),
                           16 * KiB)
    done = []
    env.sim.at(150_000.0,
               lambda: proto.issue(P.CLIENT, on_done=done.append))
    env.sim.at(30_000_000.0, lambda: None)
    env.sim.run()
    assert len(done) == 1
    assert done[0].extra.get("failed") in ("retry budget exhausted",
                                           "no live chain replicas")
    assert proto.failed == 1


def test_attach_membership_is_exclusive():
    env = P.Env()
    attach_membership(env, (1, 2))
    with pytest.raises(ValueError, match="already"):
        attach_membership(env, (1, 2))


# -- (e) functional plane ----------------------------------------------------


def _workload(nclients, nops, keys, seed):
    rng = random.Random(seed)
    out = []
    for c in range(nclients):
        ops = []
        for i in range(nops):
            key = rng.choice(keys)
            if rng.random() < 0.5:
                ops.append(("write", key, (c + 1) * 10_000 + i))
            else:
                ops.append(("read", key, None))
        out.append(ops)
    return out


def _run(kind, seed, min_ok=12, **kw):
    h = ReplicationHarness(kind, 3, seed=seed, **kw)
    for ops in _workload(3, 8, [1, 2], seed):
        h.add_client(ops)
    log = h.run()
    res = check_records(log.records)
    assert res.ok, f"{kind} seed={seed} kw={kw}:\n{res.explain()}"
    oks = sum(1 for r in log.records if r["ev"] == "ok")
    assert oks >= min_ok, f"only {oks} ops completed"
    return h


def test_functional_crash_only_silences_the_node():
    """The no-omniscience contract: at the crash step the view is
    untouched; the detector needs its full silence window before the
    view service removes the node, and activation waits out the lease."""
    h = ReplicationHarness("chain", 3, seed=0, crashes=((40, 3),))
    for ops in _workload(3, 8, [1, 2], 0):
        h.add_client(ops)
    h.run()
    det = h.views.detected_at(3)
    dead = h.membership.dead_timeout                       # 60 steps
    # silence runs from the last *delivered* heartbeat, up to ~2 emission
    # periods before the crash step; the verdict lands on a later poll
    assert det is not None
    assert 40 + dead - 2 * h.hb_every <= det <= 40 + dead + 2 * h.hb_every
    t_activate, v2 = h.views.view_log[1]
    assert v2.number == 2 and v2.members == (1, 2)
    assert t_activate > h.views.lease_until[3]             # strict wait-out
    assert 3 in h.router.failed and h.view == [1, 2]


#: functional fault grid (node ids 1..3; times are steps)
MEMBERSHIP_GRID = [
    {"crashes": ((40, 3),)},                           # tail crash
    {"crashes": ((40, 1),)},                           # head crash
    {"partitions": ((100, 260, (3,)),)},               # tail partitioned out
    {"flaps": ((2, 40, 0.4),)},                        # gray middle replica
    {"crashes": ((60, 2),), "loss": {1: 0.1}, "slow": {3: 4.0}},
]

_GRID_IDS = ["crash-tail", "crash-head", "partition", "flap", "combined"]


@pytest.mark.parametrize("fault", MEMBERSHIP_GRID, ids=_GRID_IDS)
def test_chain_linearizable_across_view_changes(fault):
    _run("chain", seed=3, **fault)


@pytest.mark.parametrize("fault", MEMBERSHIP_GRID, ids=_GRID_IDS)
def test_abd_linearizable_across_view_changes(fault):
    _run("abd", seed=5, **fault)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["chain", "abd"])
@pytest.mark.parametrize("fault", MEMBERSHIP_GRID, ids=_GRID_IDS)
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_full_membership_grid_linearizable(kind, fault, seed):
    _run(kind, seed=seed, **fault)


def test_functional_head_crash_retries_reuse_the_original_version():
    """Regression: a write un-acked at the head crash is retried at the
    NEW head, which must reuse the rid's original version (replicated
    down the chain) — assigning a fresh one re-applies the old value
    over newer committed writes."""
    for seed in (11, 13):                # the seeds that caught it
        _run("chain", seed=seed, crashes=((40, 1),))


def test_functional_partition_fences_or_expires_the_stale_tail():
    """A partitioned-out tail keeps serving only until its lease
    expires; afterwards every delivery to it is fenced, so it can never
    answer a read with pre-partition state."""
    h = _run("chain", seed=1, partitions=((100, 400, (3,)),))
    assert h.views.view.members == (1, 2)
    replica = h.replicas[3]
    assert replica.lease_until < h.steps            # self-fenced by lease


def test_abd_loses_availability_never_safety_without_quorum():
    """Crash the head and partition the tail: the detected view shrinks
    below the (fixed, original-n) quorum, so writes stall and clients
    exhaust their retry budgets — but every completed operation stays
    linearizable.  dead is terminal: healing the partition does not
    restore the quorum."""
    h = ReplicationHarness("abd", 3, seed=0, crashes=((40, 1),),
                           partitions=((80, 200, (3,)),))
    for ops in _workload(3, 8, [1, 2], 0):
        h.add_client(ops)
    log = h.run()
    res = check_records(log.records)
    assert res.ok, res.explain()
    assert h.client_errors, "expected retry exhaustion without a quorum"
    assert all(isinstance(e, RetryExhausted) for e in h.client_errors)
    assert len(h.views.view.members) < 2            # below quorum for good


def test_functional_client_backoff_is_seeded_and_bounded():
    c = ReplicationHarness("chain", 3, seed=42).add_client(
        [("write", 1, 7)])
    assert c.retry.max_attempts == 10
    d0 = [c.retry.delay(a, random.Random(9)) for a in range(10)]
    d1 = [c.retry.delay(a, random.Random(9)) for a in range(10)]
    assert d0 == d1                                  # seeded determinism
    assert max(d0) <= 8.0 * c.timeout * 1.25         # cap + jitter bound


def test_functional_fencing_is_counted():
    """Across the grid some packets straddle a view change and get
    fenced; the counter proves the fence path runs (exact counts are
    seed-dependent)."""
    total = 0
    for seed in range(4):
        h = ReplicationHarness("chain", 3, seed=seed, crashes=((40, 3),))
        for ops in _workload(3, 8, [1, 2], seed):
            h.add_client(ops)
        h.run()
        total += h.fenced
    assert total > 0


# -- (f) workload accounting -------------------------------------------------


def test_workload_books_heartbeats_as_ctrl_bytes():
    from repro.sim.workload import Scenario, run_scenario

    rep = run_scenario(Scenario(protocol="spin-write", num_clients=2,
                                requests_per_client=4, k=3,
                                membership=MembershipConfig(
                                    interval=20_000.0)))
    assert rep["ctrl_packets"] > 0
    assert rep["ctrl_bytes"] == 44 * rep["ctrl_packets"]
    assert rep["failed"] == 0
    assert rep["issued"] == (rep["completed"] + rep["in_flight"]
                             + rep["dropped"])
    # data-plane metrics must match the membership-free run exactly:
    # control traffic is additive, never competing for the ledger
    base = run_scenario(Scenario(protocol="spin-write", num_clients=2,
                                 requests_per_client=4, k=3))
    assert base["ctrl_packets"] == 0 and base["ctrl_bytes"] == 0
    assert rep["completed"] == base["completed"]
    assert rep["packets"] == base["packets"]
