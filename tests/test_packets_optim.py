"""Wire-format framing + optimizer math."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.auth import CapabilityAuthority, Rights
from repro.core.packets import (
    DEFAULT_MTU,
    DFSHeader,
    OpType,
    ReplicaCoord,
    WriteRequestHeader,
    num_packets,
    packetize_write,
)
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import warmup_cosine

AUTH = CapabilityAuthority(b"0123456789abcdef")
CAP = AUTH.issue(1, 1, 0, 1 << 30, Rights.WRITE, 2**31)


@given(st.integers(min_value=0, max_value=100_000))
@settings(max_examples=40, deadline=None)
def test_packetize_reassembles(size):
    data = np.arange(size, dtype=np.uint8)
    dfs = DFSHeader(OpType.WRITE, 9, 1, CAP)
    wrh = WriteRequestHeader(addr=0, size=size,
                             replicas=(ReplicaCoord(1, 0), ReplicaCoord(2, 0)))
    pkts = packetize_write(dfs, wrh, data)
    assert pkts[0].is_header and pkts[-1].is_completion
    assert all(p.wire_size <= DEFAULT_MTU for p in pkts)
    assert len(pkts) == num_packets(size, wrh.packed_size())
    out = np.zeros(size, np.uint8)
    for p in pkts:
        out[p.payload_offset : p.payload_offset + p.payload_size] = p.payload
    assert np.array_equal(out, data)
    # only the first packet carries DFS headers
    assert pkts[0].dfs is not None and all(p.dfs is None for p in pkts[1:])


def test_wrh_pack_unpack():
    wrh = WriteRequestHeader(
        addr=123, size=456, ec_k=3, ec_m=2, ec_index=1, seq=77,
        replicas=(ReplicaCoord(5, 1000), ReplicaCoord(6, 2000)),
    )
    back = WriteRequestHeader.unpack(wrh.pack())
    assert back == wrh


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(opt["step"]) == 150


def test_adamw_grad_clip_and_metrics():
    params = {"w": jnp.ones(4)}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    _, _, m = adamw_update(params, {"w": jnp.full(4, 100.0)}, opt, cfg)
    assert float(m["grad_norm"]) == 200.0


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert float(warmup_cosine(10, warmup=10, total=100)) == 1.0
    assert float(warmup_cosine(100, warmup=10, total=100, floor=0.1)) == \
        jnp.asarray(0.1)
    mid = float(warmup_cosine(55, warmup=10, total=100))
    assert 0.1 < mid < 1.0


def test_gradient_compression_error_feedback():
    """int8+EF: single-step error bounded by quantization step; error
    feedback drives the *accumulated* applied gradient toward the truth."""
    from repro.optim.compression import (
        compress_with_feedback, compression_ratio, decompress,
        init_error_state,
    )

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 32)) * 0.01),
         "b": jnp.asarray(rng.standard_normal(32) * 0.001)}
    err = init_error_state(g)
    # constant gradient repeated: applied sum must converge to n*g
    applied = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), g)
    n = 20
    for _ in range(n):
        comp, err = compress_with_feedback(g, err)
        applied = jax.tree.map(lambda a, d: a + d, applied, decompress(comp))
    for k in g:
        rel = float(jnp.max(jnp.abs(applied[k] / n - g[k])) /
                    jnp.max(jnp.abs(g[k])))
        assert rel < 0.02, (k, rel)
    assert compression_ratio(g) > 3.9
