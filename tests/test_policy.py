"""Composable StoragePolicy API invariants.

(a) bit-exactness — every PolicySpec preset compiled by repro.policy.timed
    reports latencies bit-identical to its hand-written predecessor
    (repro.sim.legacy, the frozen parity reference), across sizes and k;
(b) anchor guard — preset single-shot latencies must not drift from the
    recorded anchors (tests/data/policy_anchors.json);
(c) spec hygiene — validation rejects inconsistent stage combinations;
(d) mixed scenarios — several policies share one Env (and storage nodes)
    with request conservation, and size distributions drive per-request
    payloads;
(e) read path — spin-read through the timed plane, and read-after-write
    byte equality through the functional plane.
"""

import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.packets import ReplStrategy
from repro.policy import (
    Flat,
    HostAuth,
    NoAuth,
    PolicySpec,
    RS,
    SpongeAuth,
    Tree,
    compile_policy,
    preset_spec,
)
from repro.sim import legacy as L
from repro.sim import protocols as P
from repro.sim.workload import (
    KiB,
    PolicyLoad,
    Scenario,
    SizeDist,
    Workload,
    run_scenario,
)

ANCHORS = json.loads(
    (Path(__file__).parent / "data" / "policy_anchors.json").read_text()
)


def _legacy_single(name, size, k=4, m=2):
    env = P.Env()
    cfg = env.cfg
    host_overhead = cfg.pcie_latency_ns / 2 + cfg.host_notify_ns
    mk = {
        "raw-write": lambda: L.RawWriteProtocol(env, size),
        "spin-write": lambda: L.SpinAuthWriteProtocol(env, size),
        "rpc-write": lambda: L.RpcWriteProtocol(env, size),
        "rpc-rdma-write": lambda: L.RpcRdmaWriteProtocol(env, size),
        "rdma-flat": lambda: L.RdmaFlatProtocol(env, size, k),
        "cpu-ring": lambda: L.ChunkedTreeProtocol(
            env, size, k, ReplStrategy.RING, host_overhead,
            cfg.host_memcpy_GBps / 2),
        "cpu-pbt": lambda: L.ChunkedTreeProtocol(
            env, size, k, ReplStrategy.PBT, host_overhead,
            cfg.host_memcpy_GBps / 2),
        "hyperloop": lambda: L.ChunkedTreeProtocol(
            env, size, k, ReplStrategy.RING, P.HYPERLOOP_TRIGGER_NS, None,
            chunk=size, config_phase_writes=k),
        "spin-ring": lambda: L.SpinReplicationProtocol(
            env, size, k, ReplStrategy.RING),
        "spin-pbt": lambda: L.SpinReplicationProtocol(
            env, size, k, ReplStrategy.PBT),
        "spin-triec": lambda: L.SpinTriecProtocol(env, size, k, m),
        "inec-triec": lambda: L.InecTriecProtocol(env, size, k, m),
    }
    return P._run_single(mk[name](), env).latency_ns


def _piped_single(name, size, k=4, m=2):
    env = P.Env()
    proto = P.make_protocol(env, name, size, k=k, m=m)
    return P._run_single(proto, env).latency_ns


# -- (a) bit-exactness parity suite ------------------------------------------


@pytest.mark.parametrize("name", sorted(P.PROTOCOL_NAMES))
@pytest.mark.parametrize("size", [3 * KiB, 96 * KiB])
def test_pipeline_bit_exact_vs_legacy(name, size):
    k = 3 if name in ("spin-triec", "inec-triec") else 4
    legacy = _legacy_single(name, size, k=k)
    piped = _piped_single(name, size, k=k)
    assert piped == legacy, (name, size, piped, legacy)


@pytest.mark.parametrize("name", [
    "rdma-flat", "cpu-ring", "cpu-pbt", "hyperloop", "spin-ring", "spin-pbt",
])
@pytest.mark.parametrize("k", [2, 8])
def test_pipeline_bit_exact_across_k(name, k):
    size = 24 * KiB
    assert _piped_single(name, size, k=k) == _legacy_single(name, size, k=k)


@pytest.mark.parametrize("name", ["spin-triec", "inec-triec"])
@pytest.mark.parametrize("km", [(3, 2), (6, 3)])
def test_pipeline_bit_exact_ec_geometries(name, km):
    k, m = km
    size = 48 * KiB
    assert (_piped_single(name, size, k=k, m=m)
            == _legacy_single(name, size, k=k, m=m))


# -- (b) anchor drift guard --------------------------------------------------


@pytest.mark.parametrize("name", sorted(ANCHORS["latency_ns"]))
def test_preset_latency_matches_anchor(name):
    """Tier-1 guard: a preset's single-shot latency must not drift from
    its recorded anchor (regenerate tests/data/policy_anchors.json only
    for deliberate model changes)."""
    from repro.policy.spec import EC_GEOMETRY_PRESETS

    cfgd = ANCHORS["config"]
    k = cfgd["ec_k"] if name in EC_GEOMETRY_PRESETS else cfgd["k"]
    for size_s, want in ANCHORS["latency_ns"][name].items():
        got = P.run_single_shot(name, int(size_s), k=k, m=2).latency_ns
        assert got == pytest.approx(want, rel=1e-12), (name, size_s)


# -- (c) spec hygiene --------------------------------------------------------


def test_spec_validation_rejects_bad_combinations():
    with pytest.raises(ValueError, match="exclusive"):
        PolicySpec("spin", SpongeAuth(), replication=Tree(2),
                   erasure=RS(3, 2))
    with pytest.raises(ValueError, match="HostAuth"):
        PolicySpec("rdma", HostAuth())
    with pytest.raises(ValueError, match="rpc transport"):
        PolicySpec("rpc", NoAuth())
    with pytest.raises(ValueError, match="SpongeAuth"):
        PolicySpec("rdma", SpongeAuth())   # auth stage would silently drop
    with pytest.raises(ValueError, match="requires SpongeAuth"):
        PolicySpec("spin", NoAuth())       # NIC pipeline always validates
    with pytest.raises(ValueError, match="spin transport"):
        PolicySpec("rdma", NoAuth(), replication=Tree(2, engine="spin"))
    with pytest.raises(ValueError, match="unknown RS engine"):
        PolicySpec("spin", SpongeAuth(), erasure=RS(3, 2, engine="fpga"))
    with pytest.raises(ValueError, match="unknown policy preset"):
        preset_spec("warp-drive")


def test_policy_package_imports_standalone():
    """`import repro.policy` must work in a fresh interpreter (no prior
    repro.core import) — guards against the core<->policy import cycle."""
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "-c",
         "import repro.policy; repro.policy.preset_spec('spin-write')"],
        capture_output=True, text=True, timeout=120,
        env={"PYTHONPATH": str(Path(__file__).parent.parent / "src"),
             "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr


def test_checkpoint_from_spec_rejects_flat():
    from repro.checkpoint.manager import CheckpointPolicy

    flat = PolicySpec("rdma", NoAuth(), replication=Flat(3))
    with pytest.raises(ValueError, match="Flat replication"):
        CheckpointPolicy.from_spec(flat)


def test_spec_describe_and_nodes():
    spec = preset_spec("spin-triec", k=6, m=3)
    assert spec.storage_node_count == 9
    assert "RS(6,3,spin)" in spec.describe()
    assert preset_spec("rdma-flat", k=5).storage_node_count == 5
    assert preset_spec("spin-read").op == "read"


def test_client_rs_engine_has_no_timed_pipeline():
    env = P.Env()
    spec = PolicySpec("spin", SpongeAuth(), erasure=RS(3, 2, "client"))
    with pytest.raises(ValueError, match="no timed pipeline"):
        compile_policy(env, spec, 4 * KiB)


# -- (d) mixed scenarios + size distributions --------------------------------


def _conserves(rep):
    return rep["issued"] == rep["completed"] + rep["in_flight"] + rep["dropped"]


def test_mixed_policies_share_env_and_nodes():
    """Writes + EC compiled onto one Env, sharing storage node 1, with
    request conservation and per-policy accounting."""
    sc = Scenario(
        policies=[
            PolicyLoad("spin-write", 2.0,
                       SizeDist("lognormal", mean=32 * KiB)),
            PolicyLoad("spin-triec", 1.0),
        ],
        size=64 * KiB, num_clients=4, requests_per_client=6,
        k=3, m=2, seed=5,
    )
    w = Workload(sc)
    assert set(w.protos[0].storage_nodes) & set(w.protos[1].storage_nodes)
    rep = w.run()
    assert _conserves(rep)
    assert rep["completed"] == 4 * 6
    per = rep["per_policy"]
    assert set(per) == {"spin-write", "spin-triec"}
    assert sum(p["issued"] for p in per.values()) == rep["issued"]
    assert sum(p["completed"] for p in per.values()) == rep["completed"]
    assert all(p["completed"] > 0 for p in per.values())


def test_mixed_scenario_deterministic():
    sc = Scenario(
        policies=[
            PolicyLoad("spin-write", 1.0, SizeDist("bimodal")),
            PolicyLoad(preset_spec("spin-ring", k=3), 1.0),
        ],
        size=16 * KiB, num_clients=3, requests_per_client=5, k=3, seed=11,
        arrival="poisson", offered_load_GBps=20.0,
    )
    assert run_scenario(sc) == run_scenario(sc)


def test_mixed_open_loop_conserves_with_drops():
    sc = Scenario(
        policies=[
            PolicyLoad("spin-write", 1.0,
                       SizeDist("fixed", mean=256 * KiB)),
            PolicyLoad("spin-triec", 1.0,
                       SizeDist("fixed", mean=256 * KiB)),
        ],
        size=256 * KiB, num_clients=6, requests_per_client=24,
        arrival="poisson", offered_load_GBps=200.0, max_outstanding=3,
        k=3, m=2, seed=2,
    )
    rep = run_scenario(sc)
    assert rep["dropped"] > 0
    assert rep["in_flight"] == 0
    assert _conserves(rep)


def test_size_dist_sampling_properties():
    import random

    rnd = random.Random(0)
    fixed = SizeDist("fixed", mean=7 * KiB)
    assert {fixed.sample(rnd) for _ in range(8)} == {7 * KiB}
    logn = SizeDist("lognormal", mean=64 * KiB, sigma=0.6)
    xs = [logn.sample(rnd) for _ in range(4000)]
    assert all(logn.min_bytes <= x <= logn.max_bytes for x in xs)
    mean = sum(xs) / len(xs)
    assert 0.8 * 64 * KiB < mean < 1.25 * 64 * KiB
    bim = SizeDist("bimodal", small=4 * KiB, large=256 * KiB, p_large=0.25)
    ys = [bim.sample(rnd) for _ in range(2000)]
    assert set(ys) == {4 * KiB, 256 * KiB}
    frac = sum(y == 256 * KiB for y in ys) / len(ys)
    assert 0.2 < frac < 0.3
    with pytest.raises(ValueError):
        SizeDist("zipf").sample(rnd)


def test_size_dist_drives_per_request_payloads():
    """Per-request sizes actually change the wire traffic: lognormal mix
    moves a different byte volume than the fixed-size run."""
    base = dict(protocol="spin-write", size=64 * KiB, num_clients=2,
                requests_per_client=8, seed=3)
    fixed = Workload(Scenario(**base))
    fixed.run()
    mixed = Workload(Scenario(size_dist=SizeDist("lognormal", mean=64 * KiB),
                              **base))
    mixed.run()
    assert fixed.metrics.bytes_completed == 16 * 64 * KiB
    assert mixed.metrics.bytes_completed != fixed.metrics.bytes_completed
    assert len(set(mixed.metrics.latencies_ns)) > 1


def test_legacy_exclusive_claim_still_guards():
    """Legacy-style exclusive installs still refuse to share a node, and
    refuse nodes already carrying pipeline bindings."""
    env = P.Env()
    P.make_protocol(env, "spin-write", 4 * KiB)
    with pytest.raises(ValueError, match="policy-pipeline bindings"):
        env.claim_node(1, object())


# -- (e) read path -----------------------------------------------------------


def test_spin_read_timed_policy():
    res = P.run_single_shot("spin-read", 64 * KiB)
    # a read streams the object back: it must cost at least the wire time
    env_cfg_bytes_per_ns = 50.0
    assert res.latency_ns > 64 * KiB / env_cfg_bytes_per_ns
    rep = run_scenario(Scenario(protocol="spin-read", size=64 * KiB,
                                num_clients=2, requests_per_client=4))
    assert rep["completed"] == 8 and _conserves(rep)


def test_read_after_write_byte_equality_functional_plane():
    """Write through the policy engine, read back through the packet read
    path: bytes must match exactly (and unauthorized reads NACK)."""
    from repro.core.auth import CapabilityAuthority, Rights
    from repro.core.handlers import DFSClient, DFSNode, Router
    from repro.core.packets import ReplicaCoord

    auth = CapabilityAuthority(b"fedcba9876543210")
    router = Router()
    nodes = [DFSNode(i, router, auth) for i in range(4)]
    client = DFSClient(client_id=9, router=router)
    cap = auth.issue(client_id=9, object_id=1, offset=0, length=1 << 22,
                     rights=Rights.WRITE | Rights.READ, expiry=10**10)
    data = np.random.default_rng(4).integers(0, 256, 12_345, dtype=np.uint8)
    spec = preset_spec("spin-ring", k=3)
    targets = [ReplicaCoord(i, 4096) for i in range(3)]
    client.write_spec(cap, data, spec, targets)
    # read each replica back through the packet plane
    for t in targets:
        got = client.read(cap, t, data.size)
        assert np.array_equal(got, data)
    # write-only capability is NACKed on the read path
    wr_only = auth.issue(client_id=9, object_id=1, offset=0, length=1 << 22,
                         rights=Rights.WRITE, expiry=10**10)
    with pytest.raises(IOError):
        client.read(wr_only, targets[0], data.size)


def test_write_spec_flat_and_plain_plans():
    from repro.core.auth import CapabilityAuthority, Rights
    from repro.core.handlers import DFSClient, DFSNode, Router
    from repro.core.packets import ReplicaCoord

    auth = CapabilityAuthority(b"0123456789abcdef")
    router = Router()
    nodes = [DFSNode(i, router, auth) for i in range(3)]
    client = DFSClient(client_id=2, router=router)
    cap = auth.issue(client_id=2, object_id=1, offset=0, length=1 << 22,
                     rights=Rights.WRITE | Rights.READ, expiry=10**10)
    data = np.arange(5000, dtype=np.uint8) % 251
    flat = PolicySpec("rdma", NoAuth(), replication=Flat(3))
    greqs = client.write_spec(cap, data, flat,
                              [ReplicaCoord(i, 0) for i in range(3)])
    assert len(greqs) == 3          # one independent plain write per replica
    for i in range(3):
        assert np.array_equal(nodes[i].read(0, data.size), data)
