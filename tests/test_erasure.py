"""RS(k, m) coding invariants: MDS recovery, streaming == whole-stripe."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.erasure import (
    AccumulatorPool,
    RSCode,
    join_stripe,
    split_stripe,
    stream_encode,
)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),      # k
    st.integers(min_value=0, max_value=4),      # m
    st.integers(min_value=1, max_value=400),    # payload length
    st.randoms(use_true_random=False),
)
def test_any_m_losses_recover(k, m, length, rnd):
    code = RSCode(k, m)
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    parity = code.encode(data)
    shards = list(data) + list(parity)
    lost = rnd.sample(range(k + m), m)
    degraded = [None if i in lost else shards[i] for i in range(k + m)]
    assert np.array_equal(code.decode(degraded), data)


def test_more_than_m_losses_fail():
    code = RSCode(4, 2)
    data = np.arange(4 * 64, dtype=np.uint8).reshape(4, 64)
    parity = code.encode(data)
    shards = [None, None, None, data[3], parity[0], parity[1]]
    with pytest.raises(ValueError, match="unrecoverable"):
        code.decode(shards)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([(2, 1), (3, 2), (6, 3)]),
    st.integers(min_value=1, max_value=600),
    st.sampled_from([32, 64, 129]),
    st.booleans(),
)
def test_stream_encode_matches_batch(km, length, packet, interleaved):
    k, m = km
    code = RSCode(k, m)
    rng = np.random.default_rng(length * packet)
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    got = stream_encode(
        code, data, packet_payload=packet, interleaved=interleaved,
        pool_size=512,
    )
    assert np.array_equal(got, code.encode(data))


def test_reconstruct_single_shard():
    code = RSCode(5, 3)
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, (5, 96), dtype=np.uint8)
    parity = code.encode(data)
    shards = list(data) + list(parity)
    for idx in range(8):
        degraded = [s if i != idx else None for i, s in enumerate(shards)]
        rebuilt = code.reconstruct_shard(degraded, idx)
        assert np.array_equal(rebuilt, shards[idx]), idx


@given(st.binary(min_size=0, max_size=2000), st.integers(min_value=1, max_value=7))
@settings(max_examples=30, deadline=None)
def test_split_join_roundtrip(blob, k):
    chunks = split_stripe(blob, k)
    assert chunks.shape[0] == k and chunks.shape[1] % 32 == 0
    assert join_stripe(chunks, len(blob)) == blob


# -- batched decode: the m-erasure boundary (degraded reads / repair) --------


def _stripe_shards(code, data):
    """(S, k, L) data -> the k+m per-slot (S, L) shard batches."""
    parity = code.encode_stripes(data, backend="numpy")
    return ([data[:, i, :] for i in range(code.k)]
            + [parity[:, i, :] for i in range(code.m)])


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([(3, 2), (6, 3), (10, 4)]),
    st.integers(min_value=1, max_value=4),       # erasure count (capped to m)
    st.sampled_from([1, 33, 97, 255, 501]),      # odd chunk sizes
    st.integers(min_value=1, max_value=3),       # stripes per batch
    st.randoms(use_true_random=False),
)
def test_decode_stripes_roundtrip_any_le_m_erasures(km, r, length, s, rnd):
    """encode -> drop any <= m shards -> decode_stripes recovers bit-exact
    (the degraded-read invariant, batched across same-pattern stripes)."""
    k, m = km
    r = min(r, m)
    code = RSCode(k, m)
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    data = rng.integers(0, 256, (s, k, length), dtype=np.uint8)
    shards = _stripe_shards(code, data)
    lost = rnd.sample(range(k + m), r)
    degraded = [None if i in lost else shards[i] for i in range(k + m)]
    got = code.decode_stripes(degraded, backend="numpy")
    assert np.array_equal(got, data), (km, r, length, s, lost)


@settings(max_examples=9, deadline=None)
@given(
    st.sampled_from([(3, 2), (6, 3), (10, 4)]),
    st.sampled_from([31, 65, 127]),
    st.randoms(use_true_random=False),
)
def test_decode_stripes_m_erasure_boundary(km, length, rnd):
    """Exactly m erasures (the MDS boundary) recover; m+1 must raise."""
    k, m = km
    code = RSCode(k, m)
    rng = np.random.default_rng(length * k)
    data = rng.integers(0, 256, (2, k, length), dtype=np.uint8)
    shards = _stripe_shards(code, data)
    at_boundary = rnd.sample(range(k + m), m)
    degraded = [None if i in at_boundary else shards[i]
                for i in range(k + m)]
    assert np.array_equal(code.decode_stripes(degraded, backend="numpy"),
                          data)
    beyond = rnd.sample(range(k + m), m + 1)
    too_degraded = [None if i in beyond else shards[i]
                    for i in range(k + m)]
    with pytest.raises(ValueError, match="unrecoverable"):
        code.decode_stripes(too_degraded, backend="numpy")


def test_decode_stripes_jax_backend_matches_numpy():
    """The fused-kernel decode path is bit-identical to the host LUT."""
    code = RSCode(3, 2)
    rng = np.random.default_rng(8)
    data = rng.integers(0, 256, (4, 3, 129), dtype=np.uint8)
    shards = _stripe_shards(code, data)
    degraded = [None, shards[1], None, shards[3], shards[4]]
    want = code.decode_stripes(degraded, backend="numpy")
    got = code.decode_stripes(degraded, backend="jax")
    assert np.array_equal(got, want)
    assert np.array_equal(got, data)


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(3, 2), (6, 3), (10, 4)]),
    st.sampled_from([1, 33, 97, 255]),           # odd payloads stay on-kernel
    st.randoms(use_true_random=False),
)
def test_xor_reduce_bytes_aggregates_parity_reconstruction(km, length, rnd):
    """The parity-node XOR aggregation (kernel xor_reduce_bytes over the k
    scaled intermediate-parity streams) equals reconstructing that parity
    shard from the surviving k — the streaming dataflow and the decode
    solver agree at the erasure boundary, for odd chunk sizes."""
    from repro.core import gf256
    from repro.kernels import ops

    k, m = km
    code = RSCode(k, m)
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    i = rnd.randrange(m)
    inter = gf256.gf_mul_vec(data, code.parity_matrix[i][:, None])  # (k, L)
    agg = np.asarray(ops.xor_reduce_bytes(inter))
    shards = list(data) + list(code.encode(data))
    shards[k + i] = None
    assert np.array_equal(agg, code.reconstruct_shard(shards, k + i))


def test_accumulator_pool_exhaustion_and_reuse():
    pool = AccumulatorPool(2, payload_size=16)
    a = pool.allocate()
    b = pool.allocate()
    assert pool.allocate() is None          # exhausted -> CPU fallback path
    pool.xor_into(a, np.full(16, 0xAA, np.uint8))
    pool.xor_into(a, np.full(16, 0x0F, np.uint8))
    out = pool.release(a)
    assert (out == (0xAA ^ 0x0F)).all()
    c = pool.allocate()                     # freed slot is reusable and zeroed
    assert c is not None
    assert (pool.release(c) == 0).all()
    assert pool.high_watermark == 2
