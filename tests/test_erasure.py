"""RS(k, m) coding invariants: MDS recovery, streaming == whole-stripe."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.erasure import (
    AccumulatorPool,
    RSCode,
    join_stripe,
    split_stripe,
    stream_encode,
)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),      # k
    st.integers(min_value=0, max_value=4),      # m
    st.integers(min_value=1, max_value=400),    # payload length
    st.randoms(use_true_random=False),
)
def test_any_m_losses_recover(k, m, length, rnd):
    code = RSCode(k, m)
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    parity = code.encode(data)
    shards = list(data) + list(parity)
    lost = rnd.sample(range(k + m), m)
    degraded = [None if i in lost else shards[i] for i in range(k + m)]
    assert np.array_equal(code.decode(degraded), data)


def test_more_than_m_losses_fail():
    code = RSCode(4, 2)
    data = np.arange(4 * 64, dtype=np.uint8).reshape(4, 64)
    parity = code.encode(data)
    shards = [None, None, None, data[3], parity[0], parity[1]]
    with pytest.raises(ValueError, match="unrecoverable"):
        code.decode(shards)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([(2, 1), (3, 2), (6, 3)]),
    st.integers(min_value=1, max_value=600),
    st.sampled_from([32, 64, 129]),
    st.booleans(),
)
def test_stream_encode_matches_batch(km, length, packet, interleaved):
    k, m = km
    code = RSCode(k, m)
    rng = np.random.default_rng(length * packet)
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    got = stream_encode(
        code, data, packet_payload=packet, interleaved=interleaved,
        pool_size=512,
    )
    assert np.array_equal(got, code.encode(data))


def test_reconstruct_single_shard():
    code = RSCode(5, 3)
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, (5, 96), dtype=np.uint8)
    parity = code.encode(data)
    shards = list(data) + list(parity)
    for idx in range(8):
        degraded = [s if i != idx else None for i, s in enumerate(shards)]
        rebuilt = code.reconstruct_shard(degraded, idx)
        assert np.array_equal(rebuilt, shards[idx]), idx


@given(st.binary(min_size=0, max_size=2000), st.integers(min_value=1, max_value=7))
@settings(max_examples=30, deadline=None)
def test_split_join_roundtrip(blob, k):
    chunks = split_stripe(blob, k)
    assert chunks.shape[0] == k and chunks.shape[1] % 32 == 0
    assert join_stripe(chunks, len(blob)) == blob


def test_accumulator_pool_exhaustion_and_reuse():
    pool = AccumulatorPool(2, payload_size=16)
    a = pool.allocate()
    b = pool.allocate()
    assert pool.allocate() is None          # exhausted -> CPU fallback path
    pool.xor_into(a, np.full(16, 0xAA, np.uint8))
    pool.xor_into(a, np.full(16, 0x0F, np.uint8))
    out = pool.release(a)
    assert (out == (0xAA ^ 0x0F)).all()
    c = pool.allocate()                     # freed slot is reusable and zeroed
    assert c is not None
    assert (pool.release(c) == 0).all()
    assert pool.high_watermark == 2
