"""Consistency axis, timed plane: chain/ABD pipelines + spec hygiene.

(a) spec surface — validation, geometry resizing, describe(), node
    counts for Chain/Quorum;
(b) timed semantics — the NIC chain holds its latency edge over the
    host-CPU chain, CRAQ dirty reads pay the tail version round-trip,
    replica crashes reconfigure the chain (and never block ABD's
    majority), stragglers slow the chain but not the quorum;
(c) plane agreement — both planes compile from one PolicySpec.
"""

import pytest

from repro.policy import (
    Chain,
    FailureModel,
    PolicySpec,
    Quorum,
    SpongeAuth,
    preset_spec,
)
from repro.policy.functional import consistency_plan
from repro.sim import protocols as P

KiB = 1024

CHAIN_PRESETS = ["chain-spin-write", "chain-host-write", "chain-spin-read"]
ABD_PRESETS = ["abd-spin-write", "abd-spin-read"]


# -- (a) spec surface --------------------------------------------------------


def test_consistency_specs_validate():
    PolicySpec("spin", SpongeAuth(), consistency=Chain(k=3)).validate()
    PolicySpec("rdma", consistency=Chain(k=3, engine="host")).validate()
    PolicySpec("spin", SpongeAuth(), consistency=Quorum(n=5),
               op="read").validate()


def test_consistency_is_exclusive_with_other_resiliency():
    from repro.policy import RS, Tree

    with pytest.raises(ValueError, match="exclusive"):
        PolicySpec("spin", SpongeAuth(), replication=Tree(3),
                   consistency=Chain(k=3))
    with pytest.raises(ValueError, match="exclusive"):
        PolicySpec("spin", SpongeAuth(), erasure=RS(3, 2),
                   consistency=Quorum(n=3))


def test_consistency_engine_and_transport_hygiene():
    with pytest.raises(ValueError, match="unknown Chain engine"):
        PolicySpec("spin", SpongeAuth(), consistency=Chain(k=3,
                                                           engine="fpga"))
    with pytest.raises(ValueError, match="spin transport"):
        PolicySpec("rdma", consistency=Chain(k=3))
    with pytest.raises(ValueError, match="rdma transport"):
        PolicySpec("spin", SpongeAuth(),
                   consistency=Chain(k=3, engine="host"))
    with pytest.raises(ValueError, match="spin engine"):
        PolicySpec("rdma", consistency=Chain(k=3, engine="host"),
                   op="read")
    with pytest.raises(ValueError, match="needs k >= 1"):
        PolicySpec("spin", SpongeAuth(), consistency=Chain(k=0))


def test_consistency_geometry_and_description():
    spec = preset_spec("chain-spin-write", k=5)
    assert spec.consistency.k == 5
    assert spec.storage_node_count == 5
    assert "Chain(k=5" in spec.describe()
    grown = spec.with_geometry(k=7)
    assert grown.consistency.k == 7
    q = preset_spec("abd-spin-read", k=3)
    assert q.consistency.n == 3 and q.storage_node_count == 3
    assert "Quorum(n=3" in q.describe()
    with pytest.raises(ValueError, match="parity"):
        q.with_geometry(k=3, m=2)


# -- (b) timed semantics -----------------------------------------------------


def _lat(name, size, k=3, failures=None):
    return P.run_under_failures(name, size, k=k,
                                failures=failures).latency_ns


@pytest.mark.parametrize("name", CHAIN_PRESETS + ABD_PRESETS)
@pytest.mark.parametrize("size", [4 * KiB, 64 * KiB])
def test_presets_complete(name, size):
    assert _lat(name, size) > 0


@pytest.mark.parametrize("size", [4 * KiB, 64 * KiB])
def test_nic_chain_beats_host_chain(size):
    """The headline claim at single-shot scale: per-hop forwarding on
    the NIC avoids the PCIe + host-notify detour of the host chain."""
    assert _lat("chain-spin-write", size) < _lat("chain-host-write", size)


def test_chain_write_scales_with_depth():
    lat = [P.run_single_shot("chain-spin-write", 16 * KiB, k=k).latency_ns
           for k in (1, 2, 4, 6)]
    assert lat == sorted(lat)  # each hop adds latency


def test_craq_dirty_read_pays_version_roundtrip():
    """A CRAQ read at a non-tail replica resolves the version with the
    tail; a tail-pinned read (dirty_read=False) serves locally and is
    therefore strictly faster in the timed plane."""
    craq = preset_spec("chain-spin-read", k=3)
    tail_only = PolicySpec("spin", SpongeAuth(), op="read",
                           consistency=Chain(k=3, dirty_read=False))
    env_a, env_b = P.Env(), P.Env()
    from repro.policy.timed import compile_policy

    la = P._run_single(compile_policy(env_a, craq, 16 * KiB), env_a)
    lb = P._run_single(compile_policy(env_b, tail_only, 16 * KiB), env_b)
    assert lb.latency_ns < la.latency_ns


def test_chain_survives_replica_crash():
    """Any single crash reconfigures the chain; the shorter chain is
    faster than the healthy one and still completes."""
    healthy = _lat("chain-spin-write", 64 * KiB)
    for node in (1, 2, 3):
        lat = _lat("chain-spin-write", 64 * KiB,
                   failures=FailureModel(crashed=(node,)))
        assert 0 < lat < healthy


def test_chain_read_survives_tail_crash():
    lat = _lat("chain-spin-read", 64 * KiB,
               failures=FailureModel(crashed=(3,)))
    assert lat > 0


def test_chain_unrecoverable_when_all_crash():
    with pytest.raises(ValueError, match="unrecoverable"):
        _lat("chain-spin-write", 4 * KiB,
             failures=FailureModel(crashed=(1, 2, 3)))


def test_abd_tolerates_minority_crash_and_rejects_majority():
    healthy = _lat("abd-spin-write", 64 * KiB)
    crashed = _lat("abd-spin-write", 64 * KiB,
                   failures=FailureModel(crashed=(2,)))
    assert crashed == pytest.approx(healthy, rel=0.25)
    with pytest.raises(ValueError, match="unrecoverable"):
        _lat("abd-spin-write", 4 * KiB,
             failures=FailureModel(crashed=(1, 2)))


def test_straggler_slows_chain_but_not_quorum():
    """A slow tail drags the whole chain (every write commits there);
    ABD completes at the fast majority and barely notices."""
    slow_tail = FailureModel(slow=((3, 8.0),))
    chain_h = _lat("chain-spin-write", 64 * KiB)
    chain_s = _lat("chain-spin-write", 64 * KiB, failures=slow_tail)
    abd_h = _lat("abd-spin-write", 64 * KiB)
    abd_s = _lat("abd-spin-write", 64 * KiB, failures=slow_tail)
    assert chain_s > 1.5 * chain_h
    assert abd_s == pytest.approx(abd_h, rel=0.05)


# -- (c) plane agreement -----------------------------------------------------


def test_both_planes_compile_from_one_spec():
    from repro.policy.timed import compile_policy

    spec = preset_spec("chain-spin-write", k=3)
    env = P.Env()
    proto = compile_policy(env, spec, 16 * KiB)
    assert proto.storage_nodes == (1, 2, 3)
    plan = consistency_plan(spec)
    assert (plan.kind, plan.k, plan.dirty_read) == ("chain", 3, True)

    q = preset_spec("abd-spin-write", k=3)
    env = P.Env()
    proto = compile_policy(env, q, 16 * KiB)
    assert proto.storage_nodes == (1, 2, 3)
    assert consistency_plan(q).kind == "abd"


def test_consistency_presets_are_registered():
    from repro.policy import PRESET_NAMES

    for name in CHAIN_PRESETS + ABD_PRESETS:
        assert name in PRESET_NAMES
        preset_spec(name).validate()
