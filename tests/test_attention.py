"""Flash (blockwise) attention: exactness vs dense reference, fwd + custom
VJP, across GQA group counts, block sizes, and causal/bidirectional."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention, gqa_decode, gqa_init


def ref_attn(q, k, v, causal=True):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(d)
    if causal:
        m = jnp.tril(jnp.ones((s, k.shape[1]), bool))
        scores = jnp.where(m[None, None], scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vv.astype(jnp.float32)).astype(
        q.dtype
    )


CASES = [
    (2, 64, 4, 2, 16, 16, True),
    (1, 48, 8, 8, 8, 32, True),      # MHA
    (2, 64, 4, 1, 16, 16, False),    # MQA, bidirectional
    (2, 40, 6, 2, 16, 16, True),     # ragged block count
    (1, 33, 3, 3, 8, 16, True),      # non-divisible seq/block
]


@pytest.mark.parametrize("b,s,h,hkv,d,blk,causal", CASES)
def test_forward_matches_dense(b, s, h, hkv, d, blk, causal):
    rng = np.random.default_rng(s * h)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    out = blockwise_attention(q, k, v, causal, blk, 0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_attn(q, k, v, causal)),
        rtol=2e-4, atol=2e-4,
    )


@pytest.mark.parametrize("b,s,h,hkv,d,blk,causal", CASES[:3])
def test_custom_vjp_matches_autodiff(b, s, h, hkv, d, blk, causal):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)

    def loss_flash(q, k, v):
        return (blockwise_attention(q, k, v, causal, blk, 0) ** 2).sum()

    def loss_ref(q, k, v):
        return (ref_attn(q, k, v, causal) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=3e-3, atol=3e-3
        )


def test_mla_head_dims_differ():
    """V head dim != QK head dim (MLA): shapes/values still correct."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 32, 4, 24)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 32, 4, 24)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 32, 4, 16)), jnp.float32)
    out = blockwise_attention(q, k, v, True, 16, 0)
    assert out.shape == (2, 32, 4, 16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_attn(q, k, v, True)),
        rtol=2e-4, atol=2e-4,
    )


def test_decode_consistent_with_prefill():
    """Greedy decode over a cache reproduces blockwise training attention
    at the last position."""
    cfg = dict(n_heads=4, n_kv_heads=2, head_dim=16)
    d_model = 64
    p = gqa_init(jax.random.PRNGKey(0), d_model, **cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 9, d_model)), jnp.bfloat16)
    from repro.models.attention import gqa_apply

    full = gqa_apply(p, x, 4, 2, 16, rope_theta=1e4, block=8)
    # feed tokens one by one through the decode path
    ck = jnp.zeros((1, 16, 2, 16), jnp.bfloat16)
    cv = jnp.zeros((1, 16, 2, 16), jnp.bfloat16)
    outs = []
    for t in range(9):
        o, ck, cv = gqa_decode(
            p, x[:, t : t + 1], ck, cv, jnp.asarray(t, jnp.int32), 4, 2, 16,
            rope_theta=1e4,
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=0.1, atol=0.1,  # bf16 accumulation differences
    )
