"""Batched RS data-plane validation: rs_encode_stripes ≡ per-stripe
rs_encode ≡ the numpy LUT oracle, decode round-trips on batched stripes,
odd-length XOR folds on the kernel path, and the vectorized stream_encode
against the per-packet reference dataflow."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core import gf256
from repro.core.erasure import (
    RSCode,
    stream_encode,
    stream_encode_packets,
)
from repro.kernels import ops


SCHEMES = [(2, 1), (3, 2), (6, 3), (10, 4)]


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from(SCHEMES),
    st.integers(min_value=1, max_value=5),     # stripes
    st.integers(min_value=1, max_value=300),   # chunk length (incl. % 32 != 0)
)
def test_rs_encode_stripes_matches_loop_and_oracle(km, s, length):
    k, m = km
    rng = np.random.default_rng(k * 1000 + s * 100 + length)
    data = rng.integers(0, 256, (s, k, length), dtype=np.uint8)
    batched = np.asarray(ops.rs_encode_stripes(data, k, m, block_w=8))
    loop = np.stack(
        [np.asarray(ops.rs_encode(data[i], k, m, block_w=8)) for i in range(s)]
    )
    oracle = np.stack([gf256.gf_matmul(RSCode(k, m).parity_matrix, data[i])
                       for i in range(s)])
    assert np.array_equal(batched, loop)
    assert np.array_equal(batched, oracle)


@pytest.mark.parametrize("k,m", [(3, 2), (6, 3)])
def test_rs_encode_stripes_ref_backend(k, m):
    rng = np.random.default_rng(k)
    data = rng.integers(0, 256, (4, k, 100), dtype=np.uint8)
    got = np.asarray(ops.rs_encode_stripes(data, k, m, backend="ref"))
    want = np.asarray(ops.rs_encode_stripes(data, k, m, block_w=8))
    assert np.array_equal(got, want)


def test_rs_encode_stripes_m_zero():
    data = np.zeros((3, 4, 64), dtype=np.uint8)
    assert ops.rs_encode_stripes(data, 4, 0).shape == (3, 0, 64)


@settings(max_examples=8, deadline=None)
@given(
    st.sampled_from([(3, 2), (6, 3), (10, 4)]),
    st.integers(min_value=1, max_value=200),
    st.randoms(use_true_random=False),
)
def test_decode_stripes_roundtrip_random_erasures(km, length, rnd):
    k, m = km
    code = RSCode(k, m)
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    s = rng.integers(1, 5)
    data = rng.integers(0, 256, (s, k, length), dtype=np.uint8)
    parity = code.encode_stripes(data)
    shards = [data[:, i] for i in range(k)] + [parity[:, i] for i in range(m)]
    lost = rnd.sample(range(k + m), m)
    degraded = [None if i in lost else shards[i] for i in range(k + m)]
    for backend in ("jax", "numpy"):
        got = code.decode_stripes(degraded, backend=backend)
        assert np.array_equal(got, data), (km, length, lost, backend)


def test_decode_stripes_too_many_losses():
    code = RSCode(3, 2)
    data = np.zeros((2, 3, 32), dtype=np.uint8)
    parity = code.encode_stripes(data)
    degraded = [None, None, None, parity[:, 0], parity[:, 1]]
    with pytest.raises(ValueError, match="unrecoverable"):
        code.decode_stripes(degraded)


@pytest.mark.parametrize("length", [1, 3, 63, 97, 999])
def test_xor_reduce_bytes_odd_lengths_stay_on_kernel(length):
    """L % 4 != 0 pads to word granularity instead of degrading to ref."""
    rng = np.random.default_rng(length)
    x = rng.integers(0, 256, (5, length), dtype=np.uint8)
    want = np.asarray(ops.xor_reduce_bytes(x, backend="ref"))
    got = np.asarray(ops.xor_reduce_bytes(x))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("s,n,length", [(1, 2, 64), (3, 5, 100), (4, 3, 7)])
def test_xor_reduce_bytes_batched(s, n, length):
    rng = np.random.default_rng(s * n * length)
    x = rng.integers(0, 256, (s, n, length), dtype=np.uint8)
    want = np.bitwise_xor.reduce(x, axis=1)
    assert np.array_equal(np.asarray(ops.xor_reduce_bytes_batched(x)), want)
    assert np.array_equal(
        np.asarray(ops.xor_reduce_bytes_batched(x, backend="ref")), want
    )


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(2, 1), (3, 2), (6, 3)]),
    st.integers(min_value=1, max_value=500),
    st.sampled_from([32, 64, 129]),
    st.booleans(),
)
def test_stream_encode_vectorized_matches_per_packet(km, length, packet,
                                                     interleaved):
    k, m = km
    code = RSCode(k, m)
    rng = np.random.default_rng(length * packet)
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    want = stream_encode_packets(
        code, data, packet_payload=packet, interleaved=interleaved,
        pool_size=512,
    )
    got = stream_encode(
        code, data, packet_payload=packet, interleaved=interleaved,
        pool_size=512,
    )
    assert np.array_equal(got, want)
    assert np.array_equal(got, code.encode(data))


@pytest.mark.parametrize("k,m,length", [(3, 2, 100), (6, 3, 33)])
def test_gf_scale_streams_matches_lut(k, m, length):
    """The bit-sliced stream-scaling kernel (TriEC data-node stage) equals
    the broadcast LUT multiply: stream (i, j) == g[i, j] * chunk_j."""
    code = RSCode(k, m)
    rng = np.random.default_rng(k * m)
    data = rng.integers(0, 256, (k, length), dtype=np.uint8)
    got = np.asarray(ops.gf_scale_streams(code.parity_matrix, data))
    want = gf256.gf_mul_vec(code.parity_matrix[:, :, None], data[None, :, :])
    assert got.shape == (m, k, length)
    assert np.array_equal(got, want)


def test_stream_encode_jax_backend_matches():
    code = RSCode(3, 2)
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, (3, 200), dtype=np.uint8)
    got = stream_encode(code, data, packet_payload=64, backend="jax")
    assert np.array_equal(got, code.encode(data))


@pytest.mark.parametrize("interleaved", [True, False])
def test_stream_encode_pool_model_matches_per_packet(interleaved):
    """The analytical accumulator-pressure model reproduces the per-packet
    path exactly: same success/failure verdict, same fallback count."""
    code = RSCode(3, 2)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, (3, 20 * 32), dtype=np.uint8)  # 20 sequences

    def outcome(fn):
        try:
            fn(code, data, packet_payload=32, interleaved=interleaved,
               pool_size=8)
            return "ok"
        except RuntimeError as e:
            return str(e)

    assert outcome(stream_encode) == outcome(stream_encode_packets)


def test_parity_bitmatrix_memoized():
    """Same coefficient bytes -> same cached (read-only) tensor object."""
    p = gf256.cauchy_parity_matrix(3, 2)
    a = gf256.parity_bitmatrix(p)
    b = gf256.parity_bitmatrix(p.copy())
    assert a is b
    assert not a.flags.writeable
    code = RSCode(3, 2)
    assert code.parity_bitmatrix is a
