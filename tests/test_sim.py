"""Simulator validation against the paper's quantitative claims.

Acceptance bands are deliberate: the simulator is calibrated to the
paper's measured handler costs (Tables I/II) and link parameters, but CPU/
RDMA-side constants are modeled — we assert each headline *claim* holds
with margin rather than exact figures.
"""

import pytest

from repro.core.packets import ReplStrategy
from repro.core.state import (
    WRITE_DESCRIPTOR_BYTES,
    descriptor_memory_budget,
    littles_law_concurrent_writes,
    max_concurrent_writes,
)
from repro.sim import protocols as P
from repro.sim.network import NetConfig
from repro.sim.pspin import handler_budget_ns, hpus_for_line_rate

KiB = 1024


def test_fig6_spin_overhead_small_and_large():
    """sPIN <= ~30% over raw for small writes; converges for large."""
    r1 = P.run_raw_write(1 * KiB).latency_ns
    s1 = P.run_spin_auth_write(1 * KiB).latency_ns
    assert 1.0 < s1 / r1 < 1.35, s1 / r1              # paper: up to 27%
    r512 = P.run_raw_write(512 * KiB).latency_ns
    s512 = P.run_spin_auth_write(512 * KiB).latency_ns
    assert s512 / r512 < 1.05                          # approaches raw


def test_fig6_rpc_penalties():
    """RPC pays the buffering memcpy at large sizes; RPC+RDMA the extra RTT
    at small sizes."""
    size = 512 * KiB
    rpc = P.run_rpc_write(size).latency_ns
    spin = P.run_spin_auth_write(size).latency_ns
    assert rpc / spin > 1.8
    small_rr = P.run_rpc_rdma_write(1 * KiB).latency_ns
    small_spin = P.run_spin_auth_write(1 * KiB).latency_ns
    assert small_rr > small_spin


def test_fig9_flat_fast_small_spin_fast_large():
    """RDMA-Flat best <=16 KiB; sPIN wins past the crossover (paper: 16 KiB),
    approaching ~2x at 512 KiB for k=2."""
    k = 2
    flat_small = P.run_rdma_flat(4 * KiB, k).latency_ns
    spin_small = P.run_spin_replication(4 * KiB, k, ReplStrategy.RING).latency_ns
    assert flat_small < spin_small
    flat_big = P.run_rdma_flat(512 * KiB, k).latency_ns
    spin_big = P.run_spin_replication(512 * KiB, k, ReplStrategy.RING).latency_ns
    assert flat_big / spin_big > 1.4                   # paper: up to 2x


def test_fig9_k4_speedup_vs_best_alternative():
    k, size = 4, 512 * KiB
    alts = [
        P.run_rdma_flat(size, k).latency_ns,
        P.run_hyperloop(size, k).latency_ns,
        P.run_cpu_ring(size, k).latency_ns,
        P.run_cpu_pbt(size, k).latency_ns,
    ]
    spin = P.run_spin_replication(size, k, ReplStrategy.RING).latency_ns
    assert min(alts) / spin > 1.7                      # paper: up to 2.16x


def test_fig10_pbt_beats_ring_for_small_writes_large_k():
    small = 4 * KiB
    ring = P.run_spin_replication(small, 8, ReplStrategy.RING).latency_ns
    pbt = P.run_spin_replication(small, 8, ReplStrategy.PBT).latency_ns
    assert pbt < ring
    big = 512 * KiB
    ring_b = P.run_spin_replication(big, 8, ReplStrategy.RING).latency_ns
    pbt_b = P.run_spin_replication(big, 8, ReplStrategy.PBT).latency_ns
    assert ring_b < pbt_b                              # bandwidth-bound: ring wins


def test_fig9_goodput_line_rate_from_8k_and_pbt_half():
    """Ring replication ingests at ~line rate from 8 KiB writes; PBT at
    about half (2 egress copies per packet)."""
    ring8 = P.run_spin_goodput(8 * KiB, 4, ReplStrategy.RING, num_writes=96)
    assert ring8 > 0.75 * 50.0                 # near line rate from 8 KiB
    ring64 = P.run_spin_goodput(64 * KiB, 4, ReplStrategy.RING, num_writes=96)
    assert ring64 > 0.9 * 50.0                 # at line rate by 64 KiB
    pbt = P.run_spin_goodput(64 * KiB, 4, ReplStrategy.PBT, num_writes=96)
    ring = P.run_spin_goodput(64 * KiB, 4, ReplStrategy.RING, num_writes=96)
    assert 0.35 < pbt / ring < 0.65


def test_fig15_ec_latency_and_bandwidth():
    cfg = NetConfig(bandwidth_gbps=100.0)
    spin = P.run_spin_triec(512 * KiB, 3, 2, cfg=cfg).latency_ns
    inec = P.run_inec_triec(512 * KiB, 3, 2, cfg=cfg).latency_ns
    assert inec / spin > 1.8                           # paper: up to 2x
    bw_s = P.run_spin_triec(512 * KiB, 6, 3, cfg=cfg, num_blocks=12).extra[
        "bandwidth_GBps"]
    bw_i = P.run_inec_triec(512 * KiB, 6, 3, cfg=cfg, num_blocks=12).extra[
        "bandwidth_GBps"]
    assert 2.0 < bw_s / bw_i < 5.5                     # paper: 3.3x @512 KiB
    bw_s1 = P.run_spin_triec(1 * KiB, 6, 3, cfg=cfg, num_blocks=96).extra[
        "bandwidth_GBps"]
    bw_i1 = P.run_inec_triec(1 * KiB, 6, 3, cfg=cfg, num_blocks=24).extra[
        "bandwidth_GBps"]
    assert bw_s1 / bw_i1 > 15                          # paper: 29x @1 KiB


def test_handler_stats_under_load():
    """PBT handlers stall toward ~2 us under egress backpressure (Table I);
    ring handlers stay near their measured compute time."""
    pbt = P.run_spin_replication(8 * KiB, 4, ReplStrategy.PBT, num_writes=96)
    assert pbt.extra["mean_handler_ns"] > 900
    ring = P.run_spin_replication(8 * KiB, 4, ReplStrategy.RING, num_writes=96)
    assert ring.extra["mean_handler_ns"] < 450


def test_fig16_hpus_for_line_rate():
    """RS(6,3) @400 Gbit/s needs ~512 HPUs (paper section VI-C)."""
    n = hpus_for_line_rate(23018.0, 400.0)
    assert 450 <= n <= 640
    assert hpus_for_line_rate(23018.0, 200.0) <= n // 2 + 32
    assert handler_budget_ns(400.0) == pytest.approx(32 * 2048 * 8 / 400.0)


def test_fig4_littles_law_and_memory_budget():
    assert descriptor_memory_budget() == 6 * 2**20
    assert max_concurrent_writes() == (6 * 2**20) // WRITE_DESCRIPTOR_BYTES
    assert max_concurrent_writes() > 80_000            # paper: ~82 K writes
    # worst case: 1 KiB writes at 400 Gbit/s with 2 us service time
    n = littles_law_concurrent_writes(1024, 2e-6)
    assert 90 < n < 110                                # 48.8 Mpps * 2 us
