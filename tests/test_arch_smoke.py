"""Per-architecture smoke tests: reduced same-family config, one forward/
train step + one decode step on CPU, asserting output shapes and no NaNs.

(The FULL configs are exercised only via the dry-run — ShapeDtypeStruct,
no allocation.)
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import decode_step, init_cache, init_params, loss_fn

BATCH, SEQ, MAXLEN = 2, 32, 48


def _batch_for(cfg):
    toks = jnp.ones((BATCH, SEQ), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.ones((BATCH, SEQ), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((BATCH, SEQ, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.ones(
            (BATCH, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_step(name):
    cfg = ARCHS[name].smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)))(
        params
    )
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{name}: loss={loss}"
    gn = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.abs(g.astype(jnp.float32))), grads, 0.0
    )
    assert jnp.isfinite(gn), f"{name}: non-finite grads"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_decode_step(name):
    cfg = ARCHS[name].smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, BATCH, MAXLEN)
    if cfg.family == "encdec":
        cache["enc_len"] = jnp.array(8, jnp.int32)
    step = jax.jit(lambda p, c, b: decode_step(p, cfg, c, b))
    logits, cache = step(
        params, cache,
        {"tokens": jnp.ones((BATCH, 1), jnp.int32),
         "cur_len": jnp.zeros((), jnp.int32)},
    )
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert jnp.isfinite(logits).all(), name
    # second step with updated cur_len exercises the cache-append path
    logits2, _ = step(
        params, cache,
        {"tokens": jnp.ones((BATCH, 1), jnp.int32),
         "cur_len": jnp.ones((), jnp.int32)},
    )
    assert jnp.isfinite(logits2).all(), name


def test_full_configs_match_assignment():
    """Pin the exact assigned hyperparameters."""
    m = ARCHS["yi-9b"].model
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff, m.vocab) == (
        48, 4096, 32, 4, 11008, 64000)
    m = ARCHS["dbrx-132b"].model
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.vocab) == (
        40, 6144, 48, 8, 100352)
    assert (m.moe_experts, m.moe_top_k, m.moe_d_ff) == (16, 4, 10752)
    m = ARCHS["deepseek-v2-lite-16b"].model
    assert (m.n_layers, m.d_model, m.mla_kv_lora, m.moe_experts, m.moe_top_k,
            m.moe_shared) == (27, 2048, 512, 64, 6, 2)
    m = ARCHS["qwen1.5-4b"].model
    assert m.qkv_bias and (m.n_layers, m.d_model, m.n_heads, m.d_ff,
                           m.vocab) == (40, 2560, 20, 6912, 151936)
    m = ARCHS["starcoder2-7b"].model
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab) == (32, 4608, 36, 4, 18432, 49152)
    m = ARCHS["minitron-8b"].model
    assert (m.n_layers, m.d_model, m.d_ff, m.vocab) == (32, 4096, 16384, 256000)
    m = ARCHS["zamba2-2.7b"].model
    assert (m.n_layers, m.d_model, m.ssm_state, m.shared_attn_every) == (
        54, 2560, 64, 6)
    m = ARCHS["whisper-base"].model
    assert (m.n_layers, m.enc_layers, m.d_model, m.n_heads, m.d_ff,
            m.vocab) == (6, 6, 512, 8, 2048, 51865)
    m = ARCHS["xlstm-125m"].model
    assert (m.n_layers, m.d_model, m.n_heads, m.vocab) == (12, 768, 4, 50304)
    m = ARCHS["llava-next-mistral-7b"].model
    assert (m.n_layers, m.d_model, m.n_kv_heads, m.d_ff, m.vocab) == (
        32, 4096, 8, 14336, 32000)


def test_long_context_only_for_subquadratic():
    for name, arch in ARCHS.items():
        if name in ("zamba2-2.7b", "xlstm-125m"):
            assert arch.supports("long_500k"), name
        else:
            assert not arch.supports("long_500k"), name
            assert dict(arch.skip_notes).get("long_500k"), name
