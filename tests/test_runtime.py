"""Runtime: fault-tolerant training loop, straggler monitor, serving."""

import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.storage import StorageCluster
from repro.core.auth import CapabilityAuthority, Rights
from repro.data.pipeline import DataPipeline, PipelineConfig, SyntheticSource
from repro.models import ModelConfig, decode_step, init_cache, init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.serve_loop import Request, ServeLoop
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.train_loop import Trainer, TrainLoopConfig

CFG = ModelConfig("rt-tiny", "dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, loss_chunk=8, attn_block=8)


def _make_trainer(total_steps=12, ckpt_every=4):
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    adam = AdamWConfig(lr=1e-3)

    @jax.jit
    def step_fn(p, o, batch):
        loss, grads = jax.value_and_grad(lambda q: loss_fn(q, CFG, batch))(p)
        p2, o2, m = adamw_update(p, grads, o, adam)
        m["loss"] = loss
        return p2, o2, m

    pipe = DataPipeline(SyntheticSource(CFG.vocab, seed=1),
                        PipelineConfig(batch=2, seq=16))
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 24)
    mgr = CheckpointManager(cluster, CheckpointPolicy(k=3, m=2,
                                                      stripe_bytes=1 << 18))
    tr = Trainer(step_fn, params, opt, pipe, mgr,
                 TrainLoopConfig(total_steps=total_steps,
                                 checkpoint_every=ckpt_every))
    return tr, cluster


def test_training_loss_decreases():
    tr, _ = _make_trainer(total_steps=15)
    hist = tr.run()
    assert len(hist) == 15
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert np.isfinite(last) and last < first


def test_failure_restore_restart():
    """Crash at step 9 -> restore from the step-8 checkpoint -> finish."""
    tr, cluster = _make_trainer(total_steps=12, ckpt_every=4)
    fired = {"done": False}

    def inject(step, trainer):
        if step == 9 and not fired["done"]:
            fired["done"] = True
            cluster.fail_node(2)           # storage node also dies (EC absorbs)
            return True                     # compute failure
        return False

    hist = tr.run(inject_failure=inject)
    assert tr.restarts == 1
    assert tr.step == 12
    steps = [h["step"] for h in hist]
    assert steps.count(9) == 2              # step 9 was replayed after restore


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=20, factor=2.0, patience=2)
    for i in range(15):
        assert mon.record(i, 0.1) is None
    ev = mon.record(15, 0.5)
    assert ev is not None and ev.severity > 4
    assert not mon.should_mitigate
    mon.record(16, 0.5)
    assert mon.should_mitigate


def test_serve_loop_auth_and_decode():
    params = init_params(CFG, jax.random.PRNGKey(1))
    auth = CapabilityAuthority(b"0123456789abcdef")
    step = jax.jit(lambda p, c, b: decode_step(p, CFG, c, b))
    loop = ServeLoop(
        step, params, lambda: init_cache(CFG, 4, 64), batch_slots=4,
        authority=auth, eos_id=-1,
    )
    good = auth.issue(1, 0, 0, 1 << 20, Rights.READ,
                      int(time.time()) + 3600)
    bad = auth.issue(1, 0, 0, 1 << 20, Rights.WRITE,   # no READ right
                     int(time.time()) + 3600)
    reqs = [
        Request(rid=0, prompt=[1, 2, 3], max_tokens=4, capability=good),
        Request(rid=1, prompt=[4, 5], max_tokens=3, capability=good),
        Request(rid=2, prompt=[6], max_tokens=2, capability=bad),
    ]
    done = loop.run(reqs, max_steps=64)
    by_rid = {r.rid: r for r in done}
    assert by_rid[2].rejected and not by_rid[2].out
    assert len(by_rid[0].out) == 4 and len(by_rid[1].out) == 3
    assert all(0 <= t < CFG.vocab for t in by_rid[0].out)


def test_pipeline_determinism_and_seek():
    src = SyntheticSource(100, seed=9)
    p1 = DataPipeline(src, PipelineConfig(batch=2, seq=8))
    b0 = next(iter(p1))
    p1.seek(0)
    b0_again = next(iter(p1))
    assert np.array_equal(b0["tokens"], b0_again["tokens"])
    p1.close()
