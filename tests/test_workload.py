"""Multi-client workload engine invariants.

(a) determinism — same seed => identical metrics/trace
(b) single-client equivalence — N=1 closed-loop matches the single-shot
    runners (validates the engine's plumbing adds no overhead; fidelity of
    the runners to the paper's model is pinned by tests/test_sim.py)
(c) monotonicity — p99 latency non-decreasing in offered load
(d) conservation — completed + in-flight + dropped == issued
"""

import pytest

from repro.sim import protocols as P
from repro.sim.workload import KiB, Scenario, Workload, run_scenario

TRIO = ["spin-write", "spin-ring", "spin-triec"]


def _conserves(rep: dict) -> bool:
    return rep["issued"] == rep["completed"] + rep["in_flight"] + rep["dropped"]


# -- (a) determinism ---------------------------------------------------------


@pytest.mark.parametrize("arrival", ["closed", "poisson", "bursty"])
def test_same_seed_same_trace(arrival):
    sc = Scenario(protocol="spin-ring", size=16 * KiB, num_clients=4,
                  arrival=arrival, requests_per_client=12, seed=7,
                  offered_load_GBps=30.0)
    a, b = run_scenario(sc), run_scenario(sc)
    assert a == b                      # full report incl. latency-derived
    w1, w2 = Workload(sc), Workload(sc)
    r1, r2 = w1.run(), w2.run()
    assert w1.metrics.latencies_ns == w2.metrics.latencies_ns
    assert r1["events"] == r2["events"]


def test_different_seed_different_poisson_trace():
    base = dict(protocol="spin-write", size=16 * KiB, num_clients=4,
                arrival="poisson", requests_per_client=12,
                offered_load_GBps=30.0)
    a = Workload(Scenario(seed=1, **base))
    b = Workload(Scenario(seed=2, **base))
    a.run(), b.run()
    assert a.metrics.latencies_ns != b.metrics.latencies_ns


# -- (b) single-client equivalence -------------------------------------------


@pytest.mark.parametrize("protocol", sorted(P.PROTOCOL_NAMES))
@pytest.mark.parametrize("size", [4 * KiB, 128 * KiB])
def test_single_client_matches_single_shot(protocol, size):
    k = 3 if protocol in ("spin-triec", "inec-triec") else 4
    rep = run_scenario(
        Scenario(protocol=protocol, size=size, num_clients=1,
                 requests_per_client=1, k=k, m=2)
    )
    want_us = P.run_single_shot(protocol, size, k=k, m=2).latency_ns / 1e3
    assert rep["completed"] == 1 and _conserves(rep)
    assert rep["p50_us"] == pytest.approx(want_us, rel=0.01)


def test_shared_env_second_protocol_rejected():
    """Two protocols on one Env would silently steal each other's packets
    — installing over another protocol's nodes must raise."""
    env = P.Env()
    P.SpinAuthWriteProtocol(env, 4 * KiB)
    with pytest.raises(ValueError, match="already owned"):
        P.RpcWriteProtocol(env, 4 * KiB)


def test_closed_loop_request_count():
    rep = run_scenario(
        Scenario(protocol="spin-write", num_clients=3, requests_per_client=5)
    )
    assert rep["issued"] == rep["completed"] == 15
    assert _conserves(rep)


# -- (c) monotonicity --------------------------------------------------------


def test_p99_monotone_in_offered_load():
    prev = 0.0
    for load in (5.0, 15.0, 30.0, 45.0):
        rep = run_scenario(
            Scenario(protocol="spin-write", size=64 * KiB, num_clients=4,
                     arrival="poisson", offered_load_GBps=load,
                     requests_per_client=24, seed=2)
        )
        assert rep["p99_us"] >= prev - 1e-9, (load, rep["p99_us"], prev)
        prev = rep["p99_us"]


def test_p99_monotone_in_client_count():
    prev = 0.0
    for n in (1, 2, 4, 8):
        rep = run_scenario(
            Scenario(protocol="spin-ring", size=64 * KiB, num_clients=n,
                     requests_per_client=6)
        )
        assert rep["p99_us"] >= prev - 1e-9, (n, rep["p99_us"], prev)
        prev = rep["p99_us"]


def test_contention_visible_in_queues_and_goodput():
    quiet = run_scenario(
        Scenario(protocol="spin-write", size=64 * KiB, num_clients=1,
                 requests_per_client=4)
    )
    busy = run_scenario(
        Scenario(protocol="spin-write", size=64 * KiB, num_clients=16,
                 requests_per_client=4)
    )
    assert busy["ingress_queue_peak"] > quiet["ingress_queue_peak"]
    assert busy["goodput_GBps"] > quiet["goodput_GBps"]   # more offered load
    assert busy["goodput_GBps"] < 50.0                    # <= line rate


# -- (d) conservation --------------------------------------------------------


def test_conservation_with_drops():
    rep = run_scenario(
        Scenario(protocol="spin-write", size=256 * KiB, arrival="poisson",
                 offered_load_GBps=200.0, num_clients=8,
                 requests_per_client=48, max_outstanding=4, seed=1)
    )
    assert rep["dropped"] > 0                 # overload sheds load
    assert rep["in_flight"] == 0              # ran to completion
    assert _conserves(rep)


def test_conservation_with_horizon_cutoff():
    rep = run_scenario(
        Scenario(protocol="spin-write", size=256 * KiB, arrival="bursty",
                 num_clients=4, requests_per_client=32,
                 duration_ns=50_000.0)
    )
    assert rep["in_flight"] > 0               # horizon left requests pending
    assert _conserves(rep)


def test_bursty_arrivals_issue_all():
    rep = run_scenario(
        Scenario(protocol="spin-ring", size=16 * KiB, arrival="bursty",
                 num_clients=2, requests_per_client=9, burst_size=4,
                 burst_gap_ns=50_000.0)
    )
    assert rep["issued"] == 18 and _conserves(rep)
