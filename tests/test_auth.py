"""Capability authentication: issue/verify, forgery rejection, np/jnp parity."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic local shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.auth import (
    CAP_WORDS,
    Capability,
    CapabilityAuthority,
    Rights,
    sponge_mac,
)

AUTH = CapabilityAuthority(b"0123456789abcdef")


def _cap(**kw):
    base = dict(client_id=7, object_id=42, offset=0, length=1 << 20,
                rights=int(Rights.READ | Rights.WRITE), expiry=2_000_000_000)
    base.update(kw)
    return AUTH.issue(**base)


def test_verify_happy_path():
    cap = _cap()
    assert AUTH.verify(cap, now=1_700_000_000, op_rights=Rights.WRITE,
                       offset=100, length=50, client_id=7)


def test_verify_rejects_expiry_rights_extent_identity():
    cap = _cap()
    assert not AUTH.verify(cap, now=2_100_000_000, op_rights=Rights.WRITE)
    assert not AUTH.verify(cap, now=1, op_rights=Rights.DELETE)
    assert not AUTH.verify(cap, now=1, op_rights=Rights.READ,
                           offset=1 << 20, length=1)
    assert not AUTH.verify(cap, now=1, op_rights=Rights.READ, client_id=8)


@given(st.integers(min_value=0, max_value=CAP_WORDS - 1),
       st.integers(min_value=0, max_value=31))
@settings(max_examples=50, deadline=None)
def test_any_field_bitflip_is_forgery(word, bit):
    cap = _cap()
    words = cap.words().copy()
    words[word] ^= np.uint32(1 << bit)
    forged_tag = sponge_mac(words, AUTH.key)
    assert (int(forged_tag[0]), int(forged_tag[1])) != cap.tag


def test_wrong_key_rejected():
    cap = _cap()
    other = CapabilityAuthority(b"fedcba9876543210")
    assert not other.verify(cap, now=1, op_rights=Rights.READ)


def test_pack_unpack_roundtrip():
    cap = _cap(nonce=12345)
    assert Capability.unpack(cap.pack()) == cap
    assert len(cap.pack()) == Capability.PACKED_SIZE == 48


@given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                min_size=CAP_WORDS, max_size=CAP_WORDS))
@settings(max_examples=25, deadline=None)
def test_np_jnp_mac_parity(words):
    w = np.array(words, dtype=np.uint32)
    t_np = sponge_mac(w, AUTH.key, xp=np)
    t_j = np.asarray(sponge_mac(jnp.asarray(w), jnp.asarray(AUTH.key), xp=jnp))
    assert np.array_equal(t_np, t_j)


def test_bulk_verify_kernel():
    from repro.kernels import ops

    caps = [_cap(client_id=i, nonce=i) for i in range(16)]
    w = np.stack([c.words() for c in caps])
    t = np.array([c.tag for c in caps], dtype=np.uint32)
    ok = np.asarray(ops.bulk_verify(jnp.asarray(w), jnp.asarray(t),
                                    jnp.asarray(AUTH.key)))
    assert ok.all()
    t[3, 1] ^= 1
    ok2 = np.asarray(ops.bulk_verify(jnp.asarray(w), jnp.asarray(t),
                                     jnp.asarray(AUTH.key)))
    assert not ok2[3] and ok2.sum() == 15
