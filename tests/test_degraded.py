"""Failure injection, degraded reads, and repair — across every plane.

(a) timed plane — degraded-read pipelines compile their survivor fan-out
    against the FailureModel, reconstruct with the NIC decode stage, and
    hold the paper's ratios (degraded <= 2x healthy at RS(3,2) f=1;
    NIC-side reconstruction >= 2x over the host-CPU path);
(b) workload — mixed read/write scenarios share extents on one Env, and
    request/byte conservation holds under crashes and packet loss (no
    silent loss: stuck requests stay in flight, lost packets are counted);
(c) functional plane — packet-plane degraded reads are bit-exact via
    batched RSCode.decode_stripes under any <= m erasures, reconstruction
    is verified against surviving parity, repair rebuilds lost shards onto
    a replacement node, and the audit ledger partitions every written byte.
"""

import numpy as np
import pytest

from repro.policy import FailureModel, PolicySpec, ReadPolicy, RS, SpongeAuth
from repro.policy.timed import ec_read_survivors
from repro.sim import protocols as P
from repro.sim.pspin import PsPINConfig
from repro.sim.workload import KiB, PolicyLoad, Scenario, SizeDist, Workload, run_scenario

MiB = 1 << 20


def _conserves(rep):
    return rep["issued"] == rep["completed"] + rep["in_flight"] + rep["dropped"]


# -- (a) timed plane ---------------------------------------------------------


def test_failure_model_validation():
    with pytest.raises(ValueError, match="probability"):
        FailureModel(loss=((1, 1.5),))
    with pytest.raises(ValueError, match="factor"):
        FailureModel(slow=((1, 0.5),))
    assert FailureModel().is_healthy()
    assert not FailureModel(crashed=(1,)).is_healthy()


def test_read_policy_spec_validation():
    with pytest.raises(ValueError, match="degraded-rs"):
        PolicySpec("spin", SpongeAuth(), op="read",
                   read=ReadPolicy("degraded-rs"))
    with pytest.raises(ValueError, match="replica-failover"):
        PolicySpec("spin", SpongeAuth(), op="read",
                   read=ReadPolicy("replica-failover"))
    with pytest.raises(ValueError, match="unknown read mode"):
        PolicySpec("spin", SpongeAuth(), op="read",
                   read=ReadPolicy("psychic"))
    with pytest.raises(ValueError, match="only applies"):
        PolicySpec("spin", SpongeAuth(), read=ReadPolicy())
    spec = PolicySpec("spin", SpongeAuth(), erasure=RS(3, 2, "spin"),
                      op="read", read=ReadPolicy("degraded-rs"))
    assert spec.storage_node_count == 5
    assert "Read(degraded-rs,spin)" in spec.describe()


def test_ec_read_survivor_selection():
    e = RS(3, 2)
    assert ec_read_survivors(e, set()) == ([1, 2, 3], 0)
    assert ec_read_survivors(e, {2}) == ([1, 3, 4], 1)
    assert ec_read_survivors(e, {1, 3}) == ([2, 4, 5], 2)
    assert ec_read_survivors(e, {4}) == ([1, 2, 3], 0)  # parity loss: direct
    with pytest.raises(ValueError, match="unrecoverable"):
        ec_read_survivors(e, {1, 2, 4})


def test_degraded_read_latency_ordering_and_ratios():
    """The acceptance bar: at RS(3,2) with one failed data node the timed
    degraded read stays <= 2x the healthy spin-read preset, and NIC-side
    reconstruction holds >= 2x over the host-CPU path."""
    pcfg = PsPINConfig(num_hpus=256)  # line-rate decode regime (Fig. 16)
    size = MiB

    def lat(name, failures=None):
        return P.run_degraded_read(name, size, k=3, m=2, failures=failures,
                                   pcfg=pcfg).latency_ns

    healthy = lat("spin-read")
    striped = lat("spin-read-ec")
    deg1 = lat("spin-read-ec", FailureModel(crashed=(1,)))
    deg2 = lat("spin-read-ec", FailureModel(crashed=(1, 2)))
    host1 = lat("cpu-read-ec", FailureModel(crashed=(1,)))
    assert striped <= 1.05 * healthy         # healthy striped read is free
    assert healthy < deg1 < deg2             # reconstruction costs, honestly
    assert deg1 <= 2.0 * healthy             # the paper's degraded bar
    assert host1 >= 2.0 * deg1               # NIC offload holds >= 2x


def test_degraded_read_beyond_m_unrecoverable():
    with pytest.raises(ValueError, match="unrecoverable"):
        P.run_degraded_read("spin-read-ec", 64 * KiB, k=3, m=2,
                            failures=FailureModel(crashed=(1, 2, 3)))


def test_replica_failover_read():
    fo = P.run_degraded_read("spin-read-repl", 64 * KiB, k=3,
                             failures=FailureModel(crashed=(1,)))
    healthy = P.run_degraded_read("spin-read", 64 * KiB)
    assert fo.latency_ns == pytest.approx(healthy.latency_ns, rel=0.01)
    with pytest.raises(ValueError, match="unrecoverable"):
        P.run_degraded_read("spin-read-repl", 4 * KiB, k=2,
                            failures=FailureModel(crashed=(1, 2)))


def test_slow_survivor_stretches_degraded_read():
    """A straggler NIC on the decode path (the client unit, node 0) must
    slow the reconstruction — the FailureModel's slow axis is live."""
    fm = FailureModel(crashed=(1,))
    fast = P.run_degraded_read("spin-read-ec", 256 * KiB, k=3, m=2,
                               failures=fm).latency_ns
    slow = P.run_degraded_read(
        "spin-read-ec", 256 * KiB, k=3, m=2,
        failures=FailureModel(crashed=(1,), slow=((0, 4.0),)),
    ).latency_ns
    assert slow > 1.5 * fast


def test_packet_loss_counted_and_conserved():
    sc = Scenario(protocol="spin-write", size=64 * KiB, num_clients=4,
                  requests_per_client=6, seed=3,
                  failures=FailureModel(loss=((1, 0.05),), seed=11))
    rep = run_scenario(sc)
    assert rep["lost_packets"] > 0
    assert rep["lost_bytes"] > 0
    assert _conserves(rep)
    # requests that lost a packet never ack: they stay visibly in flight
    # (and their closed-loop client stops issuing — no phantom requests)
    assert rep["in_flight"] > 0
    assert rep["completed"] + rep["in_flight"] == rep["issued"] <= 24


def test_crashed_node_strands_writes_without_silent_loss():
    rep = run_scenario(
        Scenario(protocol="spin-write", size=16 * KiB, num_clients=3,
                 requests_per_client=5,
                 failures=FailureModel(crashed=(1,)))
    )
    assert rep["completed"] == 0
    assert rep["in_flight"] == 3      # one stuck request per closed loop
    assert _conserves(rep)


def test_failure_scenarios_deterministic():
    sc = Scenario(protocol="spin-write", size=64 * KiB, num_clients=4,
                  requests_per_client=8, seed=5,
                  failures=FailureModel(loss=((1, 0.1),), seed=2))
    assert run_scenario(sc) == run_scenario(sc)


# -- (b) mixed read/write over shared extents --------------------------------


def _mixed_scenario(**kw):
    base = dict(
        policies=[
            PolicyLoad("spin-write", 1.0, SizeDist("fixed", mean=96 * KiB)),
            PolicyLoad("spin-read-ec", 1.0),
        ],
        size=128 * KiB, num_clients=4, requests_per_client=6,
        k=3, m=2, seed=7, shared_extents=True,
    )
    base.update(kw)
    return Scenario(**base)


def test_shared_extents_reads_consume_written_sizes():
    w = Workload(_mixed_scenario())
    rep = w.run()
    assert _conserves(rep)
    per = rep["per_policy"]
    assert per["spin-read-ec"]["completed"] > 0
    # every completed read drew its size from a completed write's extent
    assert set(w.extents) == {96 * KiB}
    reads = per["spin-read-ec"]
    assert reads["bytes"] == reads["completed"] * 96 * KiB
    assert rep["bytes_read"] == reads["bytes"]
    assert rep["bytes_written"] == per["spin-write"]["bytes"]


def test_shared_extents_early_reads_are_shed_not_lost():
    """A read-only mix never has extents to consume: every read is shed
    and counted as a drop — conservation instead of silent loss."""
    sc = _mixed_scenario(
        policies=[PolicyLoad("spin-read-ec", 1.0)],
        num_clients=2, requests_per_client=4,
    )
    rep = run_scenario(sc)
    assert rep["dropped"] == 8 and rep["completed"] == 0
    assert rep["per_policy"]["spin-read-ec"]["dropped"] == 8
    assert _conserves(rep)


def test_mixed_degraded_reads_under_failure():
    """Writers + degraded readers share the Env while a data node is
    down: reads reconstruct (slower than healthy) and nothing leaks."""
    healthy = run_scenario(_mixed_scenario())
    degraded = run_scenario(
        _mixed_scenario(failures=FailureModel(crashed=(2,))))
    assert _conserves(healthy) and _conserves(degraded)
    h = healthy["per_policy"]["spin-read-ec"]
    d = degraded["per_policy"]["spin-read-ec"]
    assert d["completed"] > 0
    assert d["p99_us"] > h["p99_us"]  # reconstruction is visible in tails


# -- (c) functional plane ----------------------------------------------------


def _cluster_with_object(k=3, m=2, nbytes=50_000, nodes=8, seed=0):
    from repro.checkpoint.storage import StorageCluster

    rng = np.random.default_rng(seed)
    blob = rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()
    cluster = StorageCluster(num_nodes=nodes, node_capacity=1 << 22)
    layout = cluster.write_object_bulk([blob], k=k, m=m)[0]
    return cluster, layout, blob


@pytest.mark.parametrize("lost", [(0,), (4,), (0, 1), (0, 3), (3, 4)])
def test_packet_plane_degraded_read_bit_exact(lost):
    """Every <= m erasure pattern: shards fetched via authenticated
    packet reads, reconstructed via batched decode_stripes, bit-exact."""
    cluster, layout, blob = _cluster_with_object()
    coords = list(layout.data_coords) + list(layout.parity_coords)
    for slot in lost:
        cluster.fail_node(coords[slot].node)
    assert cluster.read_object(layout) == blob


def test_degraded_read_verify_catches_corruption():
    cluster, layout, blob = _cluster_with_object()
    # corrupt a surviving parity shard in place, then force reconstruction
    par = layout.parity_coords[1]
    cluster.nodes[par.node].storage.mem[par.addr] ^= 0xFF
    cluster.fail_node(layout.data_coords[0].node)
    with pytest.raises(IOError, match="reconstruction mismatch"):
        cluster.read_object(layout)
    # opting out of verification returns (possibly wrong) bytes silently
    assert cluster.read_object(layout, verify=False) == blob


def test_repair_onto_replacement_node():
    cluster, layout, blob = _cluster_with_object(nodes=8)
    used = {c.node for c in layout.data_coords + layout.parity_coords}
    dead = layout.data_coords[1].node
    replacement = next(n for n in range(8) if n not in used)
    cluster.fail_node(dead)
    stats = cluster.repair_node(dead, replacement=replacement)
    assert stats["shards"] == 1 and stats["unrecoverable"] == 0
    assert layout.data_coords[1].node == replacement
    assert dead in cluster.failed          # dead stays dead; layout moved
    assert cluster.read_object(layout) == blob
    audit = cluster.audit()
    assert audit["readable_bytes"] == audit["bytes_written"]


def test_healthy_ec_read_skips_parity_traffic():
    """A fully healthy EC read fetches only the k data shards — parity
    nodes see no read requests on the fast path."""
    cluster, layout, blob = _cluster_with_object()
    assert cluster.read_object(layout) == blob
    for coord in layout.parity_coords:
        events = [e.kind for e in cluster.nodes[coord.node].events]
        assert "read_done" not in events


def test_background_repair_invalid_replacement_raises_on_caller():
    """Argument validation happens before the repair thread spawns, and a
    repair that died never reads as success via stale stats."""
    cluster, layout, _ = _cluster_with_object(nodes=8)
    dead = layout.data_coords[0].node
    other = layout.data_coords[1].node
    cluster.fail_node(dead)
    cluster.fail_node(other)
    with pytest.raises(ValueError, match="is failed"):
        cluster.repair_node(dead, replacement=other, background=True)


def test_background_repair_in_place():
    cluster, layout, blob = _cluster_with_object()
    dead = layout.parity_coords[0].node
    cluster.fail_node(dead)
    assert cluster.repair_node(dead, background=True) is None
    stats = cluster.repair_wait()
    assert stats["shards"] >= 1
    assert dead not in cluster.failed
    assert cluster.read_object(layout) == blob


def test_in_place_repair_beyond_tolerance_pins_object_lost():
    """Re-provisioning a node whose shards cannot be reconstructed must
    not resurrect zeroed shards as readable: the object is pinned lost,
    reads raise, and the audit ledger keeps the bytes in lost_bytes."""
    cluster, layout, blob = _cluster_with_object(k=3, m=2)
    dead = [layout.data_coords[0], layout.parity_coords[0],
            layout.parity_coords[1]]
    for coord in dead:
        cluster.fail_node(coord.node)       # 3 > m: unrecoverable
    stats = cluster.repair_node(dead[0].node)   # in-place re-provision
    assert stats["unrecoverable"] == 1 and stats["shards"] == 0
    assert layout.lost
    with pytest.raises(IOError, match="lost"):
        cluster.read_object(layout)
    audit = cluster.audit()
    assert audit["lost_bytes"] == len(blob)
    assert audit["readable_bytes"] == 0


def test_deep_shed_read_run_does_not_recurse():
    """A long closed-loop run of shed reads iterates through the event
    queue instead of recursing one stack frame per request."""
    sc = _mixed_scenario(
        policies=[PolicyLoad("spin-read-ec", 1.0)],
        num_clients=1, requests_per_client=1200,
    )
    rep = run_scenario(sc)
    assert rep["dropped"] == 1200 and _conserves(rep)


def test_background_repair_serializes_with_foreground_writes():
    """The repair thread and foreground packet-plane ops share the I/O
    lock: a write issued while a repair is in flight must not lose acks
    to interleaved router drains."""
    cluster, layout, blob = _cluster_with_object(nodes=8)
    dead = layout.parity_coords[0].node
    cluster.fail_node(dead)
    cluster.repair_node(dead, background=True)
    lay2 = cluster.write_object_bulk([blob], k=3, m=2)[0]
    assert cluster.repair_wait()["shards"] >= 1
    assert cluster.read_object(layout) == blob
    assert cluster.read_object(lay2) == blob


def test_audit_partitions_every_written_byte():
    cluster, layout, blob = _cluster_with_object(k=3, m=2)
    a = cluster.audit()
    assert a["readable_bytes"] == a["bytes_written"] == len(blob)
    cluster.fail_node(layout.data_coords[0].node)
    a = cluster.audit()
    assert a["reconstructable_bytes"] == len(blob) and a["lost_bytes"] == 0
    cluster.fail_node(layout.data_coords[1].node)
    cluster.fail_node(layout.parity_coords[0].node)
    a = cluster.audit()
    assert a["lost_bytes"] == len(blob)    # beyond m: accounted, not silent
    with pytest.raises((ValueError, IOError)):
        cluster.read_object(layout)


def test_placement_avoids_failed_nodes_and_write_retries():
    """New objects never land on crashed nodes, and a write whose layout
    was placed *before* the crash re-places on live nodes and retries
    (the mid-save crash race of the resilient-training loop)."""
    from repro.checkpoint.storage import StorageCluster

    cluster = StorageCluster(num_nodes=9, node_capacity=1 << 22)
    blob = np.arange(40_000, dtype=np.uint8) % 251
    cluster.fail_node(2)
    lay = cluster.write_object_bulk([blob.tobytes()], k=3, m=2)[0]
    nodes = {c.node for c in lay.data_coords + lay.parity_coords}
    assert 2 not in nodes
    assert cluster.read_object(lay) == blob.tobytes()
    # placement done, THEN the node dies, THEN the shards are written:
    from repro.core.packets import Resiliency

    stale = cluster.meta.create_object(
        int(blob.size), Resiliency.ERASURE_CODING, 3, 2)
    cluster.fail_node(stale.data_coords[0].node)
    orig = cluster.meta.create_object
    calls = {"n": 0}

    def place(*a, **kw):
        calls["n"] += 1
        return stale if calls["n"] == 1 else orig(*a, **kw)

    cluster.meta.create_object = place
    try:
        lay2 = cluster.write_object(blob.tobytes(), k=3, m=2)
    finally:
        cluster.meta.create_object = orig
    assert calls["n"] == 2                    # the write re-placed and retried
    assert stale.object_id not in cluster.meta._objects  # dead layout dropped
    nodes2 = {c.node for c in lay2.data_coords + lay2.parity_coords}
    assert not (nodes2 & cluster.failed)
    assert cluster.read_object(lay2) == blob.tobytes()


def test_checkpoint_restore_batches_degraded_decode():
    """CheckpointManager.restore routes every same-pattern stripe of a
    leaf through one batched decode_stripes call and survives m losses."""
    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.storage import StorageCluster

    cluster = StorageCluster(num_nodes=8, node_capacity=1 << 23)
    mgr = CheckpointManager(
        cluster, CheckpointPolicy(k=4, m=2, stripe_bytes=1 << 14))
    rng = np.random.default_rng(3)
    tree = {"w": rng.standard_normal((96, 128)).astype(np.float32)}
    mgr.save(1, tree, blocking=True)
    cluster.fail_node(0)
    cluster.fail_node(5)
    got = mgr.restore(1, treedef=tree)
    assert np.array_equal(got["w"], tree["w"])


# -- functional-plane reads under live packet loss (bounded retry) -----------


def _lossy_cluster(objects=6, loss=((0, 0.6), (1, 0.6), (2, 0.6)), seed=1):
    from repro.checkpoint.storage import StorageCluster

    rng = np.random.default_rng(7)
    cluster = StorageCluster(num_nodes=6, node_capacity=1 << 22)
    blobs = [rng.integers(0, 256, 64 * KiB, dtype=np.uint8).tobytes()
             for _ in range(objects)]
    layouts = cluster.write_object_bulk(blobs, k=3, m=2)
    cluster.set_failures(FailureModel(loss=loss, seed=seed))
    return cluster, layouts, blobs


def test_lossy_reads_retry_and_recover_bit_exact():
    """A lossy link drops shard reads; the bounded retry budget recovers
    them and the retries are counted in the audit ledger."""
    cluster, layouts, blobs = _lossy_cluster()
    assert cluster.read_objects(layouts) == blobs
    audit = cluster.audit()
    assert audit["read_retries"] > 0
    assert audit["read_retries"] == cluster.read_retries
    # no shard exhausted its budget at this loss rate/seed
    assert audit["read_timeouts"] == 0


def test_total_loss_times_out_into_degraded_reconstruction():
    """100% loss towards one node exhausts the retry budget (the
    functional-plane timeout); the read falls into the degraded decode
    path and still returns bit-exact data."""
    cluster, layouts, blobs = _lossy_cluster(loss=((0, 1.0),))
    assert cluster.read_objects(layouts) == blobs
    audit = cluster.audit()
    assert audit["read_timeouts"] > 0
    # every timed-out shard first burned its whole retry budget
    assert cluster.read_retries >= (cluster.max_read_retries
                                    * cluster.read_timeouts)


def test_lossy_reads_deterministic():
    """The loss draw is seeded: identical clusters produce identical
    retry/timeout ledgers."""
    a, la, _ = _lossy_cluster()
    b, lb, _ = _lossy_cluster()
    a.read_objects(la)
    b.read_objects(lb)
    assert (a.read_retries, a.read_timeouts) == (b.read_retries,
                                                 b.read_timeouts)


def test_set_failures_crashes_and_losses():
    """FailureModel attach: crashed nodes blackhole (degraded reads
    reconstruct), lossy nodes retry — both at once, all accounted."""
    cluster, layouts, blobs = _lossy_cluster(loss=((0, 0.5),))
    cluster.set_failures(FailureModel(crashed=(1,), loss=((0, 0.5),), seed=1))
    assert cluster.read_objects(layouts) == blobs
    audit = cluster.audit()
    assert 1 in cluster.failed
    assert audit["readable_bytes"] + audit["reconstructable_bytes"] \
        + audit["lost_bytes"] == audit["bytes_written"]


def test_paced_repair_throttles_rebuild():
    """RepairPacer bounds the rebuild byte rate: the same governor the
    workload engine paces its background loads with, on the wall clock
    (injected here so the test is instant and deterministic)."""
    from repro.control import RepairPacer

    cluster, layouts, blobs = _lossy_cluster(loss=())
    t = {"now": 0.0}
    slept = []

    def sleep(s):
        slept.append(s)
        t["now"] += s

    pacer = RepairPacer(rate_MBps=0.5, burst_bytes=32 * KiB,
                        clock=lambda: t["now"], sleep=sleep)
    dead = layouts[0].data_coords[0].node
    cluster.fail_node(dead)
    stats = cluster.repair_node(dead, pacer=pacer)
    assert stats["paced_wait_s"] > 0 and slept
    assert stats["paced_wait_s"] == pytest.approx(sum(slept))
    # the configured rate held: total wall time >= bytes / rate (minus
    # the initial burst allowance)
    assert t["now"] >= (stats["bytes"] - 32 * KiB) / 0.5e6
    for lay, blob in zip(layouts, blobs):
        assert cluster.read_object(lay) == blob


def test_paced_repair_interleaves_with_foreground_reads():
    """The pacer's wait is served *outside* the cluster I/O lock, and
    the node stays failed until write-back completes: a foreground read
    issued mid-rebuild acquires the lock, treats the half-rebuilt node
    as missing, and reconstructs correct bytes (never zeroed shards)."""
    from repro.control import RepairPacer

    cluster, layouts, blobs = _lossy_cluster(loss=())
    dead = layouts[0].data_coords[0].node
    cluster.fail_node(dead)
    mid_reads = []

    def sleep(_s):
        # runs between shard write-backs, with the lock released
        assert dead in cluster.failed
        mid_reads.append(cluster.read_objects(layouts) == blobs)

    t = {"now": 0.0}
    pacer = RepairPacer(rate_MBps=0.5, burst_bytes=16 * KiB,
                        clock=lambda: t["now"], sleep=sleep)
    cluster.repair_node(dead, pacer=pacer)
    assert mid_reads and all(mid_reads)
    assert dead not in cluster.failed
    assert cluster.read_objects(layouts) == blobs
