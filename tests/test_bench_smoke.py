"""Bench-harness smoke tests: imports stay clean under tier-1, the
dataplane sweep emits a schema-stable JSON artifact, and run.py --json
writes any bench table as a BENCH_*.json artifact.  Tiny shapes only."""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_bench_modules_import_clean():
    sys.path.insert(0, str(REPO))
    try:
        import benchmarks.contention  # noqa: F401
        import benchmarks.dataplane  # noqa: F401
        import benchmarks.degraded  # noqa: F401
        import benchmarks.mixed  # noqa: F401
        import benchmarks.paper_figs  # noqa: F401
        import benchmarks.run  # noqa: F401
    finally:
        sys.path.remove(str(REPO))


def test_dataplane_sweep_schema():
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.dataplane import sweep
    finally:
        sys.path.remove(str(REPO))
    result = sweep(codes=((3, 2),), stripes=(1, 2), chunk_sizes=(256,),
                   repeats=1)
    assert result["bench"] == "dataplane"
    assert result["metric"] == "bytes_per_s"
    assert {"backend", "interpret", "rows"} <= set(result)
    assert len(result["rows"]) == 2
    for row in result["rows"]:
        assert {
            "code", "k", "m", "stripes", "chunk_bytes", "data_bytes",
            "per_stripe_us", "batched_us", "per_stripe_bytes_per_s",
            "batched_bytes_per_s", "speedup",
        } <= set(row)
        assert row["batched_bytes_per_s"] > 0
        assert row["per_stripe_bytes_per_s"] > 0
    json.dumps(result)  # artifact must be JSON-serializable


def test_run_py_json_artifact(tmp_path):
    out = tmp_path / "BENCH_fig4.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fig4",
         "--json", str(out)],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(out.read_text())
    assert doc["bench"] == "paper_figs"
    assert doc["rows"], "no rows emitted"
    for row in doc["rows"]:
        assert {"name", "us_per_call", "derived"} <= set(row)
    assert any(r["name"].startswith("fig4/") for r in doc["rows"])


def test_run_py_degraded_artifact(tmp_path):
    """run.py --degraded emits the BENCH_degraded.json artifact with the
    gated claims (degraded <= 2x healthy; NIC >= 2x over host-CPU)."""
    out = tmp_path / "BENCH_degraded.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fig4",
         "--degraded", "--degraded-quick", "--degraded-out", str(out)],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(out.read_text())
    assert doc["bench"] == "degraded"
    names = [r["name"] for r in doc["rows"]]
    assert any(n.startswith("degraded/rs3.2/f1/spin") for n in names)
    assert any(n.startswith("degraded/mixed/") for n in names)
    assert any(n.startswith("degraded/repair/") for n in names)
    claims = doc["claims"]
    assert claims["rs32_f1_vs_healthy"] <= 2.0
    assert claims["rs32_f1_host_over_spin"] >= 2.0


def test_run_py_mixed_artifact(tmp_path):
    """run.py --mixed sweeps the mixed write+EC scenario on one shared
    Env and always writes the BENCH_mixed.json artifact."""
    out = tmp_path / "BENCH_mixed.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fig4",
         "--mixed", "--mixed-out", str(out)],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(out.read_text())
    assert doc["bench"] == "mixed"
    names = [r["name"] for r in doc["rows"]]
    assert any(n.startswith("mixed/write+ec/") for n in names)
    assert any(n.startswith("mixed/spin-triec/") for n in names)
    for row in doc["rows"]:
        assert {"name", "us_per_call", "derived"} <= set(row)
