"""Anchor-drift gate: deterministic-model anchors + benchmark floors.

Ten checks, each with a readable diff on failure:

  1. policy latency anchors — re-runs every preset/size recorded in
     ``tests/data/policy_anchors.json`` through the timed plane (the sim
     is deterministic, so these must match to ``--rel-tol``);
  2. ``BENCH_dataplane.json`` floors — the committed batched-vs-per-stripe
     speedups must stay above ``--dataplane-floor`` at S >= 8 (the PR 2
     regression bar, with slack for timing noise across machines);
  3. ``BENCH_degraded.json`` claims — degraded-read latency at RS(3,2)
     with one failed node stays <= ``--degraded-ceiling`` x the healthy
     spin-read, and NIC-side reconstruction holds >= ``--offload-floor`` x
     over the host-CPU path;
  4. ``BENCH_mixed.json`` — schema sanity (rows present, goodput > 0);
  5. ``BENCH_control.json`` claims — the Fig. 16 reproduction: the
     goodput-vs-HPUs curve saturates at >= ``--fig16-floor`` of line
     rate with the knee within one doubling of the analytic handler
     model, the SLO autoscaler converges within one doubling of the
     static-optimal HPU count for >= 3 PolicySpec presets, and paced
     background repair keeps the foreground p99 within the configured
     SLO while the unpaced stream violates it;
  6. ``BENCH_replication.json`` claims — NIC-offloaded chain replication
     holds >= ``--replication-floor`` x over the host-CPU chain both
     healthy and with one crashed replica, and every functional-plane
     history across the fault grid was linearizable;
  7. ``BENCH_membership.json`` claims — heartbeat-driven detection lands
     within the timeout budget at every swept interval, failover loses
     zero writes with the unavailability window bounded, the false-dead
     rate under a lossy monitor stays <= ``--fp-dead-ceiling`` (while
     suspicion provably flickered), and every cross-view functional
     history was linearizable with epoch fencing actually exercised;
  8. ``BENCH_namespace.json`` claims — the metadata plane: NIC-handler
     lookups hold >= ``--ns-edge-floor`` x the host-RPC path's QPS at
     saturation, the goodput-vs-clients sweep shows a measured
     namespace-saturation knee pinned on the host metadata cap, and the
     detected-view re-replication run (heartbeat-detected crash, paced
     copies) lost zero blocks with every block restored to target
     replication and metadata wire bytes booked as control traffic;
  9. ``BENCH_simspeed.json`` claims — the engine race: the batched core
     holds >= ``--simspeed-floor`` x the discrete reference's
     simulated-bytes-per-wall-second on the Fig. 16 anchor (counts
     asserted identical at generation time), and the 1000-node /
     1000-client fleet sweep finishes under ``--fleet-wall-ceiling``
     wall seconds so it stays a commit-time check;
  10. ``BENCH_trace.json`` claims — observability stays honest: tracing
     at 1/64 sampling costs <= ``--trace-overhead-ceiling`` of the
     untraced wall on the Fig. 16 anchor (the tracer records intervals
     the model already computed, never schedules events), and the
     span-level attribution explains >= ``--trace-explained-floor`` of
     the spin-vs-host write edge via the removed PCIe + host-CPU time.

Usage (CI invokes this as its own workflow step):

  PYTHONPATH=src python tools/check_anchors.py [--repo DIR]
      [--rel-tol 1e-9] [--dataplane-floor 2.0]
      [--degraded-ceiling 2.0] [--offload-floor 2.0]
      [--fig16-floor 0.85] [--replication-floor 1.5]
      [--fp-dead-ceiling 0.02] [--ns-edge-floor 1.5]
      [--simspeed-floor 5.0] [--fleet-wall-ceiling 90]
      [--trace-overhead-ceiling 0.05] [--trace-explained-floor 0.5]

Exit code 0 == no drift.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check_policy_anchors(path: str, rel_tol: float) -> list[str]:
    from repro.policy.spec import EC_GEOMETRY_PRESETS
    from repro.sim.protocols import run_single_shot

    with open(path) as f:
        anchors = json.load(f)
    cfgd = anchors["config"]
    errors = []
    for name in sorted(anchors["latency_ns"]):
        k = cfgd["ec_k"] if name in EC_GEOMETRY_PRESETS else cfgd["k"]
        for size_s, want in anchors["latency_ns"][name].items():
            got = run_single_shot(name, int(size_s), k=k, m=cfgd["m"]).latency_ns
            drift = abs(got - want) / max(abs(want), 1e-12)
            if drift > rel_tol:
                errors.append(
                    f"  {name} @ {size_s} B: anchored {want:.3f} ns, "
                    f"got {got:.3f} ns (drift {drift:.2e} > {rel_tol:.0e})"
                )
    return errors


def check_dataplane(path: str, floor: float) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    errors = []
    rows = [r for r in doc.get("rows", []) if r.get("stripes", 0) >= 8]
    if not rows:
        errors.append("  no S >= 8 rows in BENCH_dataplane.json")
    for r in rows:
        if r["speedup"] < floor:
            errors.append(
                f"  {r['code']} S={r['stripes']} chunk={r['chunk_bytes']}: "
                f"batched speedup {r['speedup']:.2f}x < floor {floor:.2f}x"
            )
    return errors


def check_degraded(path: str, ceiling: float, offload_floor: float) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    claims = doc.get("claims", {})
    errors = []
    ratio = claims.get("rs32_f1_vs_healthy")
    if ratio is None:
        errors.append("  claim rs32_f1_vs_healthy missing")
    elif ratio > ceiling:
        errors.append(
            f"  degraded RS(3,2) f=1 read is {ratio:.2f}x the healthy "
            f"spin-read (> ceiling {ceiling:.2f}x)"
        )
    off = claims.get("rs32_f1_host_over_spin")
    if off is None:
        errors.append("  claim rs32_f1_host_over_spin missing")
    elif off < offload_floor:
        errors.append(
            f"  NIC-side reconstruction only {off:.2f}x over the host-CPU "
            f"path (< floor {offload_floor:.2f}x)"
        )
    return errors


def check_mixed(path: str) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    errors = []
    if not rows:
        errors.append("  no rows in BENCH_mixed.json")
    agg = [r for r in rows if r["name"].startswith("mixed/write+ec/")]
    if not agg:
        errors.append("  no aggregate mixed/write+ec rows")
    for r in agg:
        if float(r["derived"]) <= 0:
            errors.append(f"  {r['name']}: goodput {r['derived']} <= 0")
    return errors


def check_control(path: str, fig16_floor: float) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    claims = doc.get("claims", {})
    errors = []
    frac = claims.get("fig16_goodput_frac")
    if frac is None:
        errors.append("  claim fig16_goodput_frac missing")
    elif frac < fig16_floor:
        errors.append(
            f"  fig16 goodput saturates at {frac:.3f} of line rate "
            f"(< floor {fig16_floor:.2f})"
        )
    gain = claims.get("fig16_saturation_gain")
    if gain is None:
        errors.append("  claim fig16_saturation_gain missing")
    elif gain > 1.05:
        errors.append(
            f"  fig16 curve still gaining {gain:.3f}x at the last HPU "
            f"doubling (not saturated)"
        )
    if not claims.get("fig16_knee_within_doubling"):
        errors.append(
            f"  fig16 knee ({claims.get('fig16_knee_hpus')} HPUs) not "
            f"within a doubling of the analytic model "
            f"({claims.get('fig16_model_knee_hpus')} HPUs)"
        )
    within = claims.get("autoscale_within_doubling", 0)
    if within < 3:
        errors.append(
            f"  autoscaler within one doubling of static-optimal for only "
            f"{within} presets (< 3): "
            f"{claims.get('autoscale_presets')}"
        )
    slo = claims.get("pacing_slo_p99_us")
    paced = claims.get("paced_fg_p99_us")
    unpaced = claims.get("unpaced_fg_p99_us")
    if None in (slo, paced, unpaced):
        errors.append("  pacing claims missing")
    else:
        if paced > slo:
            errors.append(
                f"  paced repair: foreground p99 {paced:.1f} us exceeds "
                f"the {slo:.1f} us SLO"
            )
        if unpaced <= slo:
            errors.append(
                f"  unpaced repair no longer violates the SLO "
                f"({unpaced:.1f} us <= {slo:.1f} us) — the experiment "
                f"lost its contrast"
            )
    return errors


def check_replication(path: str, floor: float) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    claims = doc.get("claims", {})
    errors = []
    for key, state in (("chain_nic_over_host_healthy", "healthy"),
                       ("chain_nic_over_host_f1", "with one crashed "
                                                  "replica")):
        edge = claims.get(key)
        if edge is None:
            errors.append(f"  claim {key} missing")
        elif edge < floor:
            errors.append(
                f"  NIC chain only {edge:.2f}x over the host-CPU chain "
                f"{state} (< floor {floor:.2f}x)"
            )
    if not claims.get("all_linearizable"):
        errors.append(
            f"  functional-plane histories not all linearizable "
            f"({claims.get('linearizable_ok')} of "
            f"{claims.get('linearizable_runs')} runs ok)"
        )
    if claims.get("ops_checked", 0) <= 0:
        errors.append("  linearizability proof checked zero operations "
                      "(vacuous)")
    return errors


def check_membership(path: str, fp_ceiling: float) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    claims = doc.get("claims", {})
    errors = []
    if not claims.get("detection_within_budget"):
        errors.append("  crash detection exceeded dead_timeout + 2*interval "
                      "for some heartbeat interval")
    if not claims.get("failover_zero_failed_writes"):
        errors.append("  failover lost writes (some requests reported "
                      "failed or never completed)")
    worst = claims.get("failover_worst_over_budget")
    if worst is None:
        errors.append("  claim failover_worst_over_budget missing")
    elif worst > 4.0:
        errors.append(
            f"  worst write latency during failover is {worst:.2f}x the "
            f"detection+backoff budget (> 4.0x)")
    fp = claims.get("fp_dead_rate")
    if fp is None:
        errors.append("  claim fp_dead_rate missing")
    elif fp > fp_ceiling:
        errors.append(
            f"  false-dead rate {fp:.4f} under the lossy monitor "
            f"(> ceiling {fp_ceiling:.4f})")
    if claims.get("fp_suspects_total", 0) <= 0:
        errors.append("  lossy-monitor run produced zero false suspicions "
                      "(the FP channel was not exercised — vacuous)")
    if not claims.get("membership_all_linearizable"):
        errors.append(
            f"  cross-view histories not all linearizable "
            f"({claims.get('membership_linearizable_ok')} of "
            f"{claims.get('membership_linearizable_runs')} runs ok)")
    if claims.get("membership_ops_checked", 0) <= 0:
        errors.append("  cross-view linearizability proof checked zero "
                      "operations (vacuous)")
    if claims.get("membership_fenced_total", 0) <= 0:
        errors.append("  no delivery was ever epoch-fenced across the "
                      "grid — the fencing path went untested")
    if claims.get("membership_view_changes", 0) <= 0:
        errors.append("  no view change activated across the grid — the "
                      "reconfiguration path went untested")
    return errors


def check_namespace(path: str, edge_floor: float) -> list[str]:
    if not os.path.exists(path):
        return [f"  missing artifact {path}"]
    with open(path) as f:
        doc = json.load(f)
    claims = doc.get("claims", {})
    errors = []
    edge = claims.get("ns_nic_over_host_qps")
    if edge is None:
        errors.append("  claim ns_nic_over_host_qps missing")
    elif edge < edge_floor:
        errors.append(
            f"  NIC lookups only {edge:.2f}x the host-RPC path at "
            f"saturation (< floor {edge_floor:.2f}x)")
    if not claims.get("ns_knee_detected"):
        errors.append("  no namespace-saturation knee detected in the "
                      "goodput-vs-clients sweep")
    if not claims.get("ns_knee_meta_bound"):
        errors.append(
            f"  host goodput ceiling does not match the metadata cap "
            f"(top {claims.get('ns_goodput_host_top_GBps')} GB/s vs host "
            f"cap {claims.get('ns_host_qps_cap')} lookups/s) — the knee "
            f"is not metadata-bound")
    if not claims.get("ns_rereplication_detected"):
        errors.append("  the datanode crash was never detected via "
                      "heartbeats (re-replication ran omnisciently or "
                      "not at all)")
    if claims.get("ns_rereplication_blocks", 0) <= 0:
        errors.append("  re-replication moved zero blocks (vacuous)")
    if not claims.get("ns_rereplication_zero_lost"):
        errors.append("  blocks lost across detected-view re-replication")
    if not claims.get("ns_rereplication_restored"):
        errors.append("  not every block returned to target replication "
                      "(or re-read mismatched) after re-replication")
    if not claims.get("ns_rereplication_within_budget"):
        errors.append("  re-replication violated the RepairPacer budget")
    if claims.get("ns_rereplication_unrecoverable", 0) != 0:
        errors.append("  some blocks were unrecoverable (all replicas "
                      "dead) — the scenario lost data by construction")
    if claims.get("ns_ctrl_bytes", 0) <= 0:
        errors.append("  metadata RPCs booked zero control bytes — the "
                      "ctrl_* separation went untested")
    return errors


def check_simspeed(path: str, speedup_floor: float,
                   fleet_wall_ceiling: float) -> list[str]:
    """The engine-speed gate: the batched core must hold its
    simulated-bytes-per-wall-second edge over the discrete reference on
    the Fig. 16 anchor, and the 1000-node / 1000-client fleet sweep must
    fit inside the CI smoke budget (it IS a commit-time check)."""
    from repro.bench import gate_claims

    errors = gate_claims(path, [
        ("batched_speedup_x", ">=", speedup_floor,
         "batched engine lost its speed edge over discrete"),
        ("fleet_wall_s", "<=", fleet_wall_ceiling,
         "1000-node fleet sweep blew the CI smoke budget"),
        ("fleet_nodes", ">=", 1000, "fleet sweep shrank below 1000 nodes"),
        ("fleet_clients", ">=", 1000,
         "fleet sweep shrank below 1000 clients"),
    ])
    return errors


def check_trace(path: str, overhead_ceiling: float,
                explained_floor: float) -> list[str]:
    """The observability gate: tracing must stay near-free at 1/64
    sampling (it only records intervals the model already computed) and
    the attribution must keep explaining the spin-vs-host write edge
    from the removed PCIe + host-CPU spans."""
    from repro.bench import gate_claims

    errors = gate_claims(path, [
        ("trace_overhead_frac", "<=", overhead_ceiling,
         "tracing overhead on the Fig. 16 anchor blew its ceiling"),
        ("write_edge_explained_frac", ">=", explained_floor,
         "spans no longer explain the spin-vs-host write edge"),
        ("trace_anchor_dropped", "<=", 0,
         "anchor run overflowed the span buffer (spans dropped)"),
        ("trace_anchor_spans", ">=", 1,
         "anchor run recorded no spans at 1/64 sampling"),
    ])
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--repo", default=REPO)
    ap.add_argument("--rel-tol", type=float, default=1e-9,
                    help="policy-anchor relative tolerance (the sim is "
                         "deterministic; drift means a model change)")
    ap.add_argument("--dataplane-floor", type=float, default=2.0,
                    help="min batched speedup at S >= 8")
    ap.add_argument("--degraded-ceiling", type=float, default=2.0,
                    help="max degraded/healthy read ratio at RS(3,2) f=1")
    ap.add_argument("--offload-floor", type=float, default=2.0,
                    help="min NIC-over-host degraded reconstruction ratio")
    ap.add_argument("--fig16-floor", type=float, default=0.85,
                    help="min saturated goodput as a fraction of line rate")
    ap.add_argument("--replication-floor", type=float, default=1.5,
                    help="min NIC-over-host chain-replication latency edge")
    ap.add_argument("--fp-dead-ceiling", type=float, default=0.02,
                    help="max false-dead verdicts per lossy-monitor run")
    ap.add_argument("--ns-edge-floor", type=float, default=1.5,
                    help="min NIC-over-host lookup QPS edge at saturation")
    ap.add_argument("--simspeed-floor", type=float, default=5.0,
                    help="min batched-over-discrete simulated-bytes-per-"
                         "wall-second speedup on the Fig. 16 anchor")
    ap.add_argument("--fleet-wall-ceiling", type=float, default=90.0,
                    help="max wall seconds for the 1000-node fleet sweep")
    ap.add_argument("--trace-overhead-ceiling", type=float, default=0.05,
                    help="max relative wall cost of tracing at 1/64 "
                         "sampling on the Fig. 16 anchor")
    ap.add_argument("--trace-explained-floor", type=float, default=0.5,
                    help="min fraction of the spin-vs-host write edge "
                         "explained by removed PCIe + host-CPU spans")
    args = ap.parse_args()

    checks = [
        ("policy latency anchors", check_policy_anchors(
            os.path.join(args.repo, "tests", "data", "policy_anchors.json"),
            args.rel_tol)),
        ("BENCH_dataplane.json floors", check_dataplane(
            os.path.join(args.repo, "BENCH_dataplane.json"),
            args.dataplane_floor)),
        ("BENCH_degraded.json claims", check_degraded(
            os.path.join(args.repo, "BENCH_degraded.json"),
            args.degraded_ceiling, args.offload_floor)),
        ("BENCH_mixed.json sanity", check_mixed(
            os.path.join(args.repo, "BENCH_mixed.json"))),
        ("BENCH_control.json claims", check_control(
            os.path.join(args.repo, "BENCH_control.json"),
            args.fig16_floor)),
        ("BENCH_replication.json claims", check_replication(
            os.path.join(args.repo, "BENCH_replication.json"),
            args.replication_floor)),
        ("BENCH_membership.json claims", check_membership(
            os.path.join(args.repo, "BENCH_membership.json"),
            args.fp_dead_ceiling)),
        ("BENCH_namespace.json claims", check_namespace(
            os.path.join(args.repo, "BENCH_namespace.json"),
            args.ns_edge_floor)),
        ("BENCH_simspeed.json claims", check_simspeed(
            os.path.join(args.repo, "BENCH_simspeed.json"),
            args.simspeed_floor, args.fleet_wall_ceiling)),
        ("BENCH_trace.json claims", check_trace(
            os.path.join(args.repo, "BENCH_trace.json"),
            args.trace_overhead_ceiling, args.trace_explained_floor)),
    ]
    failed = False
    for title, errors in checks:
        status = "FAIL" if errors else "ok"
        print(f"[{status:>4}] {title}")
        for e in errors:
            print(e)
        failed = failed or bool(errors)
    if failed:
        print("\nanchor drift detected: regenerate the anchors/artifacts "
              "only for deliberate model changes (and say so in the PR).")
        return 1
    print("\nall anchors hold.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
